"""Iterative (peeling) erasure decoder for real-valued LDPC codes, in JAX.

The classic peeling decoder resolves degree-1 checks one at a time.  On TPU
we use the equivalent *flooding* schedule: in each round, every parity check
with exactly one erased neighbour resolves that neighbour.  The fixed number
of rounds ``D`` is exactly the paper's decoding-iteration knob — the quality
of the recovered gradient is monotone in ``D`` (Remark 3).

Backend matrix (``backend=`` on :func:`peel_decode` /
:func:`peel_decode_adaptive` / :func:`peel_decode_batch` /
:func:`peel_decode_batch_adaptive`):

=========  ==================================================================
backend    what runs
=========  ==================================================================
"dense"    the original reference: three dense ``H``-structured ops per
           round (mask matvec, matmul, argmax) — O(p·N·V) work.  Always
           available, including for raw ``(H, Hb)`` tuples.  Batched decode
           vmaps the whole fixed-D loop over the pattern axis; batched
           ADAPTIVE decode vmaps the early-exit while_loop (per-slot
           predicates — a converged slot's carry freezes while stragglers
           keep peeling).
"sparse"   gathers over the code's padded neighbor table
           (``LDPCCode.check_idx`` / ``check_coeff``) — O(p·r_max·V) work,
           i.e. proportional to the Tanner-graph edge count, the complexity
           the paper's low-cost-decoding argument assumes.  Requires an
           :class:`LDPCCode` (the table is built at construction).  Batched
           decode vmaps the loop with the neighbor table broadcast (loaded
           once, shared across all B patterns).  Batched ADAPTIVE decode
           keeps the scatter-free batch-major round and threads a per-slot
           ACTIVE mask through a single while_loop: converged slots'
           columns are frozen (select, no gather feedback) and the loop
           exits when every slot has converged or exhausted its budget.
"pallas"   the fused one-kernel decodes (:mod:`repro.kernels.ldpc_peel`):
           the whole decode runs inside a single ``pallas_call`` with ``H``
           resident in VMEM — no per-round kernel relaunch or re-padding.
           Fixed-D (``peel_decode``), early-exit adaptive
           (``peel_decode_adaptive``: in-kernel while_loop on the
           unresolved count), batched (``peel_decode_batch``: grid over
           the B independent erasure patterns with the H tile shared across
           the batch), and batched-adaptive
           (``peel_decode_batch_adaptive``: grid over slots, one in-kernel
           while_loop PER SLOT with a traced per-slot round budget) are
           each ONE launch.  Runs in interpret mode off-TPU (correct but
           not fast on CPU).
"pallas_tiled"
           the same four one-launch contracts with ``H`` STREAMED over
           CHECK tiles from HBM (``bp`` rows at a time, double-buffered
           DMA) while the value carry lives in VMEM — problem size is
           bounded by HBM, not whole-H-in-VMEM, so the fused decode serves
           N ∈ {4096, 8192, 16384, ...}.  Identical erasure trajectories
           (every tile's proposal is computed against the round-start
           state; ascending tiles keep the lowest-index-check tie-break);
           values match "pallas" up to f32 summation order (XLA may block
           a tile's row-sum reduction differently than the whole-H one).
           Tile knobs: ``bp`` (check-tile height; default sized from the
           VMEM budget via :func:`pick_tile_bp`) and ``bv`` (payload tile).
"pallas_seeded"
           the same four one-launch contracts with NO ``H`` operand at all:
           each ``bp×N`` check tile is REGENERATED in-register from the
           code's counter-based seed inside the flooding round
           (:func:`repro.kernels.ldpc_peel.seeded_h_tile`).  Requires a
           seeded parity-only code — ``make_seeded_ldpc`` (materialized,
           ``kind="ldpc-seeded"``) or the structure-only
           :class:`repro.core.ldpc.SeededLDPC`, which never builds H at
           any size.  Erasure trajectories are bit-identical to every
           other backend on the same code and VALUES are bit-identical to
           "pallas_tiled" (same tile-shaped summation); H costs zero bytes
           of HBM storage and operand traffic.

           ``seeded_mode`` sub-dispatches the ROUND implementation:

           * "dense_tile" (default) — regenerate the full ``bp×N`` tile and
             run the tiled round's dense contractions on it (MXU-friendly,
             but O(p·N) FLOPs per round even though only r of N entries
             per check row are nonzero);
           * "gather" — generate only the r (column, weight) pairs per
             check row from the seed and run the check pass as gather +
             segment-sum, merging resolutions through the layered
             permutation's INVERSE map (first-tile-wins, lowest-check
             tie-break preserved) — O(p·r) FLOPs per round, the
             edge-proportional cost the paper's low-overhead-decoding
             claim assumes.  Erasure trajectories (masks AND round counts)
             are bit-identical to "dense_tile"; decoded values agree up to
             f32 summation order.
           * "auto" — crossover rule from :mod:`repro.core.hwcaps`:
             "gather" iff the dense round's modeled FLOPs exceed
             ``mxu_advantage ×`` the gather round's (advantage 1.0 on
             CPU/interpret — gather always wins; 8.0 placeholder on TPU
             until ROADMAP item 5's profiling replaces it).
"replay"   straight-line numeric REPLAY of a pattern-compiled
           :class:`PeelSchedule` — no round loop, no convergence test, no
           solvability counting: the elimination order is a pure function
           of ``(code, erasure pattern)``, so :func:`compile_peel_schedule`
           solves it ONCE symbolically (host-side numpy) and the replay
           executors run only the resolving checks' gather/FMA arithmetic,
           O(resolved edges) total.  Pass the schedule explicitly
           (``schedule=`` / per-slot ``schedules=``, e.g. from a
           :class:`repro.core.schedule_cache.ScheduleCache` hit — required
           under jit, where the mask is a tracer) or let a concrete mask
           solve on the fly.  Values are BIT-IDENTICAL to the flooding
           backends: single-pattern replay applies the "hi" duplicate-check
           tie-break (matching dense/sparse last-write-wins scatters),
           batched replay the "lo" rule (matching the batch-major scan and
           the Pallas kernels); adaptive round counts reproduce the
           while_loop's stopping rule, probe round included.  On TPU the
           batched replay can also run as ONE fused ``pallas_call``
           (:func:`repro.kernels.ldpc_peel.peel_decode_replay_pallas`).
           Requires an :class:`LDPCCode`.
"auto"     "dense" for raw tuples and small codes (N < 256); "sparse" for
           large codes off-TPU; on TPU, "pallas_seeded" whenever the code
           carries a regenerable seed, else "pallas" when
           :func:`vmem_bytes_estimate` says the resident kernel's
           per-grid-step working set fits the VMEM budget
           (``vmem_budget_bytes``, default 8 MiB of the ~16 MiB/core), and
           "pallas_tiled" otherwise.  A structure-only
           :class:`~repro.core.ldpc.SeededLDPC` resolves to
           "pallas_seeded" on EVERY platform (it is the only backend that
           can run without H; off-TPU it runs in interpret mode).  The
           same rule applies on the batch axis (the batched kernel's
           per-step working set matches the single-pattern kernel's), and
           to the batched-adaptive decode.
=========  ==================================================================

Memory cost per backend (H-side, f32): "dense"/"sparse"/"pallas" hold the
materialized ``(p, N)`` H (or its neighbor table) resident — HBM storage
AND per-round operand traffic scale as ``p·N``; "pallas_tiled" still
STORES ``p·N`` in HBM but holds only ``2·bp·N`` in VMEM, streaming the
rest; "pallas_seeded" stores a few ints (the seed/spec) and moves ZERO H
bytes — storage and traffic are both O(1) in the code size.

All backends follow bit-identical erasure trajectories (solvability is an
exact count of erased neighbours, and every backend resolves the same
first-erased-column neighbour per check); decoded values agree up to f32
summation order.  The batched entry point decodes each pattern exactly as
the single-pattern entry point would — ``decode_batch`` of B patterns and a
Python loop of B ``decode`` calls land on the same trajectories.

The decoder is fully ``jit``-able (fixed ``D`` → ``lax.fori_loop``;
adaptive → ``lax.while_loop`` with early exit) and batched over symbol
payloads: ``values`` may be ``(N,)`` scalars (the paper's inner products) or
``(N, V)`` vectors (coded gradient aggregation, where each symbol is a chunk
of a partial gradient).  :func:`peel_decode_batch` adds the second,
orthogonal batch axis — B *independent erasure patterns* decoded in one
launch, the serving-side concurrency axis (many coded queries, each with its
own straggler realization).

Erased coordinates that remain unresolved are left as-is in ``values`` but
flagged in the returned mask; callers zero-fill per the paper's Scheme 2
(both ``ĉ`` and ``b̂`` are zeroed on the unresolved set so the estimate stays
an unbiased scaled gradient — Lemma 1).  The encode→erase→decode→epilogue
composition lives one layer up in :mod:`repro.core.engine`.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ldpc import (
    LDPCCode,
    SeededLDPC,
    SeededStructure,
    seeded_structure_of,
)
from repro.obs import metrics as _obs_metrics

__all__ = [
    "DecodeResult",
    "PeelSchedule",
    "compile_peel_schedule",
    "erasure_mask_key",
    "peel_round",
    "peel_round_sparse",
    "peel_round_sparse_batch",
    "peel_fixed_dense",
    "peel_fixed_sparse",
    "peel_decode",
    "peel_decode_adaptive",
    "peel_decode_batch",
    "peel_decode_batch_adaptive",
    "erased_after",
    "resolve_backend",
    "vmem_bytes_estimate",
    "pick_tile_bp",
    "SEEDED_MODES",
]

BACKENDS = ("auto", "dense", "sparse", "pallas", "pallas_tiled",
            "pallas_seeded", "replay")
# Sub-dispatch of "pallas_seeded": how each flooding round is computed.
SEEDED_MODES = ("auto", "dense_tile", "gather")

# "auto" picks the sparse neighbor-table round once the dense round's O(p·N)
# work clearly loses to O(p·r_max) gathers; below this the dense matmul's
# better vectorization wins on CPU.
_AUTO_SPARSE_MIN_N = 256
# VMEM budget the "auto" dispatch sizes the fused kernels against: half of
# the ~16 MiB/core, leaving headroom for the pipeline's own double
# buffering.  Overridable per call/engine via ``vmem_budget_bytes``.
_DEFAULT_VMEM_BUDGET_BYTES = 8 * 2**20


def _kernel_shape(code) -> tuple[int, int]:
    """(p, N) of an LDPCCode / SeededLDPC, an (H, Hb) tuple, or a raw
    (p, N) int pair."""
    if isinstance(code, (LDPCCode, SeededLDPC)):
        return code.p, code.N
    a, b = code
    if isinstance(a, (int, np.integer)):
        return int(a), int(b)
    return a.shape[0], a.shape[1]


def vmem_bytes_estimate(code, dtype=jnp.float32, batch: int = 1, *,
                        bv: int = 128) -> int:
    """Estimated per-grid-step VMEM working set of the RESIDENT fused kernel.

    ``code`` may be an :class:`LDPCCode`, an ``(H, Hb)`` tuple, or a raw
    ``(p, N)`` shape pair.  The resident kernel keeps several ``(p, N)``
    buffers live per round (H itself plus its boolean mask, the column/row
    iotas, and the resolution one-hot) alongside the ``(N, bv)`` payload
    carry and the ``(N, 1)`` masks; the estimate counts them at the
    kernel's f32 compute width (``dtype`` below f32 still computes in f32).
    The batch axis shares H and streams one slot's payload per grid step,
    so ``batch`` does not scale the per-step set — the argument is accepted
    (and validated) so call sites can pass their batch size symmetrically.

    ``backend="auto"`` compares this against ``vmem_budget_bytes`` to pick
    resident-"pallas" vs "pallas_tiled"; benchmarks use it to fail over
    with a clear message instead of crashing past the VMEM limit.
    """
    if batch < 1:
        raise ValueError(f"batch must be >= 1; got {batch}")
    p, N = _kernel_shape(code)
    esize = max(jnp.dtype(dtype).itemsize, 4)
    Npad = N + (-N) % 128
    ppad = p + (-p) % 8
    h_like = 5 * ppad * Npad * esize        # H, Hb, col/row iota, one-hot
    payload = 3 * Npad * bv * esize         # carry + known + scattered
    masks = 3 * Npad * esize                # erasure mask + resolved flags
    return h_like + payload + masks


def pick_tile_bp(code, *, vmem_budget_bytes: int | None = None) -> int:
    """Check-tile height for the tiled kernels: the tallest 8-aligned tile
    whose double-buffered ``(2, bp, N)`` stream stays within ~half of the
    VMEM budget (the other half holds the value carry and round
    temporaries).  Clamped to [8, p]."""
    budget = vmem_budget_bytes or _DEFAULT_VMEM_BUDGET_BYTES
    p, N = _kernel_shape(code)
    Npad = N + (-N) % 128
    bp = (budget // 2) // (2 * Npad * 4)
    bp -= bp % 8
    return int(max(8, min(bp, p + (-p) % 8)))


class DecodeResult(NamedTuple):
    values: jax.Array  # (N,) / (N, V); batched: (B, N) / (B, N, V)
    erased: jax.Array  # (N,) bool (batched: (B, N)); True where unresolved
    # () int32 (== D for fixed-D decode); the batched-adaptive decode
    # returns the PER-SLOT vector (B,) int32 — each slot's own round count.
    rounds_used: jax.Array


def _expand(values: jax.Array) -> tuple[jax.Array, bool]:
    if values.ndim == 1:
        return values[:, None], True
    return values, False


def resolve_backend(backend: str, code, *, adaptive: bool = False,
                    vmem_budget_bytes: int | None = None) -> str:
    """Resolve the ``backend=`` knob to a concrete decode implementation.

    See the module docstring for the matrix.  Raises on unknown names and on
    sparse/pallas requests for raw ``(H, Hb)`` tuples (no neighbor table).
    Since the adaptive decode gained its own fused kernel (in-kernel
    while_loop), ``adaptive`` no longer downgrades "pallas".  On TPU,
    ``"auto"`` dispatches on :func:`vmem_bytes_estimate` against
    ``vmem_budget_bytes`` (not a hardcoded N threshold): resident "pallas"
    while the whole working set fits, "pallas_tiled" beyond it.
    """
    del adaptive  # kept for call-site compatibility; all modes have kernels
    if backend not in BACKENDS:
        raise ValueError(f"unknown decode backend {backend!r}; want one of {BACKENDS}")
    requested = backend
    is_code = isinstance(code, LDPCCode)
    seeded_h = isinstance(code, SeededLDPC) or (
        is_code and code.kind == "ldpc-seeded")
    if backend == "auto":
        if isinstance(code, SeededLDPC):
            # Structure-only: no H exists at any size — the seeded kernel
            # is the only backend that can run it (interpret off-TPU).
            backend = "pallas_seeded"
        elif not is_code:
            backend = "dense"
        elif jax.default_backend() == "tpu":
            if seeded_h:
                backend = "pallas_seeded"
            else:
                budget = vmem_budget_bytes or _DEFAULT_VMEM_BUDGET_BYTES
                backend = ("pallas" if vmem_bytes_estimate(code) <= budget
                           else "pallas_tiled")
        else:
            backend = "sparse" if code.N >= _AUTO_SPARSE_MIN_N else "dense"
    if backend == "pallas_seeded" and not seeded_h:
        kind = code.kind if is_code else type(code).__name__
        raise ValueError(
            "backend='pallas_seeded' needs a seeded parity-only code "
            "(make_seeded_ldpc / SeededLDPC) whose H is regenerable from "
            f"its seed; got {kind!r}")
    if isinstance(code, SeededLDPC) and backend != "pallas_seeded":
        raise ValueError(
            f"backend={backend!r} needs a materialized H, but a SeededLDPC "
            "is structure-only; use backend='pallas_seeded'/'auto' or build "
            "the code with make_seeded_ldpc")
    if backend in ("sparse", "pallas", "pallas_tiled", "replay") and not is_code:
        raise ValueError(
            f"backend={backend!r} needs an LDPCCode (neighbor table); "
            "raw (H, Hb) tuples only support backend='dense'"
        )
    reg = _obs_metrics.active()
    if reg is not None:
        # One increment per RESOLUTION (construction/trace), not per decode:
        # jit-cache hits re-run nothing, so counts track dispatch decisions.
        reg.counter("decoder.resolve_total",
                    requested=requested, resolved=backend).inc()
    return backend


# --------------------------------------------------------------- dense round


def peel_round(
    H: jax.Array, Hb: jax.Array, values: jax.Array, erased: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """One flooding round (dense). values: (N, V), erased: (N,) bool.

    For every check row with exactly one erased neighbour ``j``:
      ``c_j = -(sum_{j' known} H[i, j'] c_{j'}) / H[i, j]``.
    Rows that resolve the same coordinate write consistent values (they are
    parity checks of the same codeword), so duplicate scatters are benign.
    """
    N = values.shape[0]
    e = erased.astype(H.dtype)  # (N,)
    cnt = Hb.astype(H.dtype) @ e  # (p,) number of erased neighbours per check
    solvable = cnt == 1.0  # (p,)
    known = values * (1.0 - e)[:, None]  # zero out erased entries
    row_sums = H @ known  # (p, V)
    # The (unique) erased neighbour of each row; arbitrary for non-solvable rows.
    pos = jnp.argmax(Hb & erased[None, :], axis=1)  # (p,)
    coeff = jnp.take_along_axis(H, pos[:, None], axis=1)[:, 0]  # (p,)
    new_val = -row_sums / jnp.where(coeff == 0.0, 1.0, coeff)[:, None]
    # Out-of-bounds scatter with mode="drop" discards non-solvable rows.
    safe_pos = jnp.where(solvable, pos, N)
    values = values.at[safe_pos].set(new_val, mode="drop")
    erased = erased.at[safe_pos].set(False, mode="drop")
    return values, erased


@partial(jax.jit, static_argnames=("iters",))
def peel_fixed_dense(H, Hb, values, erased, iters: int):
    """``iters`` dense flooding rounds as one jitted loop.

    Operands are plain arrays (shardable / usable inside foreign jit
    contexts — this is what the sharded launch steps call); ``values``
    (N, V), ``erased`` (N,) bool.
    """
    def body(_, carry):
        v, e = carry
        return peel_round(H, Hb, v, e)

    values, erased = jax.lax.fori_loop(0, iters, body, (values, erased))
    return values, erased


# -------------------------------------------------------------- sparse round


def _edge_sum(nv: jax.Array, w: jax.Array) -> jax.Array:
    """Known-neighbor contribution sum over the r_max slot axis (axis 1).

    ``nv (rows, r_max, ...)`` gathered neighbor values, ``w (rows, r_max)``
    pre-masked edge weights (0 on erased/padding slots).  Evaluated as the
    canonical left-to-right multiply-add chain with the ADDS inside a
    ``lax.scan`` and the products outside it.  Two codegen hazards make a
    plain reduce/unrolled chain produce different last-ulp bits for the
    SAME row depending on how many rows the operands carry: XLA re-blocks
    reductions by shape, and LLVM contracts mul+add pairs into FMAs
    shape-dependently inside fused loops (``optimization_barrier`` is
    removed by the CPU pipeline before fusion, so it cannot pin either).
    Fusion never crosses a while-loop boundary, so the scan body holds
    only adds/subs/compares with no multiply to contract, and the
    products are lone muls — every output element is the same fixed IEEE
    op sequence at ANY row count.  This shape-stability is what lets
    ``backend="replay"`` recompute only the resolving checks' rows
    bit-identically to the full flooding rounds.  The body runs Neumaier
    compensated summation, so the sum is also ~1 ulp from exact — tighter
    than the reduce it replaces, keeping the cross-backend (dense/pallas)
    agreement tolerances comfortable.
    """
    wx = w.reshape(w.shape + (1,) * (nv.ndim - w.ndim))
    pt = jnp.moveaxis(nv * wx, 1, 0)                # (r_max, rows, ...)

    def body(carry, x):
        s, c = carry
        t = s + x
        big = jnp.abs(s) >= jnp.abs(x)
        c = c + jnp.where(big, (s - t) + x, (x - t) + s)
        return (t, c), None

    (s, c), _ = jax.lax.scan(body, (pt[0], jnp.zeros_like(pt[0])), pt[1:])
    return s + c


def peel_round_sparse(
    check_idx: jax.Array,
    check_coeff: jax.Array,
    values: jax.Array,
    erased: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """One flooding round via neighbor-table gathers — O(p·r_max·V) work.

    ``check_idx (p, r_max) int32`` holds each check's neighbour columns in
    ascending order, padded with the sentinel ``N``; ``check_coeff`` the
    matching edge weights, padded with 0.  Gathers read from ``values`` /
    ``erased`` padded by one sentinel row, so padding slots contribute
    nothing and no branching is needed.  Semantics match :func:`peel_round`
    exactly: same solvability decisions, same resolved neighbour per check.
    """
    N = values.shape[0]
    dt = values.dtype
    e_pad = jnp.concatenate([erased, jnp.zeros((1,), erased.dtype)])  # (N+1,)
    v_pad = jnp.concatenate([values, jnp.zeros((1, values.shape[1]), dt)])
    ne = e_pad[check_idx]  # (p, r_max) bool — erased neighbours
    nef = ne.astype(dt)
    cnt = nef.sum(axis=1)  # (p,)
    nv = v_pad[check_idx]  # (p, r_max, V)
    # Known-neighbour contribution: coeff * value, erased slots zeroed.
    sums = _edge_sum(nv, check_coeff.astype(dt) * (1.0 - nef))
    # First erased neighbour slot (ascending column order == dense argmax).
    slot = jnp.argmax(ne, axis=1)  # (p,)
    pos = jnp.take_along_axis(check_idx, slot[:, None], axis=1)[:, 0]
    coeff = jnp.take_along_axis(check_coeff, slot[:, None], axis=1)[:, 0].astype(dt)
    solvable = cnt == 1.0
    new_val = -sums / jnp.where(coeff == 0.0, 1.0, coeff)[:, None]
    safe_pos = jnp.where(solvable, pos, N)
    values = values.at[safe_pos].set(new_val, mode="drop")
    erased = erased.at[safe_pos].set(False, mode="drop")
    return values, erased


@partial(jax.jit, static_argnames=("iters",))
def peel_fixed_sparse(check_idx, check_coeff, values, erased, iters: int):
    """``iters`` sparse (neighbor-table) flooding rounds as one jitted loop.

    Operands are plain arrays (the table may be sharded over checks), so
    launch-layer steps can call this inside their own jit with explicit
    shardings; ``values`` (N, V), ``erased`` (N,) bool.
    """
    def body(_, carry):
        v, e = carry
        return peel_round_sparse(check_idx, check_coeff, v, e)

    values, erased = jax.lax.fori_loop(0, iters, body, (values, erased))
    return values, erased


# ------------------------------------------------- pattern-compiled replay


class PeelSchedule:
    """Pre-solved peeling elimination order for ONE ``(code, erasure)`` pair.

    The flooding trajectory — which check resolves which variable in which
    round — is a pure function of the code structure and the erasure mask,
    never of the payload values.  :func:`compile_peel_schedule` runs that
    trajectory ONCE symbolically (host-side numpy, to fixpoint) and records,
    per resolved variable: its flooding round (``offsets`` delimits the
    per-round segments, so replay parallelizes within a round), its gathered
    neighbor columns, and the pre-masked edge weights — under BOTH duplicate
    -check tie-break rules, since the existing backends differ:

    * ``idx_hi``/``w_hi``/``coeff_hi`` — HIGHEST check row wins, matching
      the single-pattern dense/sparse rounds (``.at[pos].set`` duplicate
      scatters are last-write-wins, and check rows scatter in ascending
      order);
    * ``idx_lo``/``w_lo``/``coeff_lo`` — LOWEST check row wins, matching
      the batch-major round's first-match candidate scan and the Pallas
      kernels' ``min``-row merges.

    Duplicate winners write consistent values (parity checks of one
    codeword), so the choice only pins f32 rounding — keeping both rules
    lets replay reproduce each backend family bit-for-bit.

    Because flooding is monotone (a round that resolves nothing ends the
    decode), the resolving rounds form a prefix: replay under a smaller
    round budget is simply a prefix slice of the same schedule.

    Instances hash/compare by IDENTITY (the arrays are frozen after
    construction).  The replay executors receive the schedule's numeric
    arrays as RUNTIME operands (:func:`_sched_ops`), so jit specializes on
    the per-round segment SHAPES only: patterns that resolve the same
    number of variables per round share one compiled executable, and XLA
    cannot constant-fold the replay arithmetic into different roundings
    than the flooding rounds it must match bit-for-bit.  That protection
    covers the library's own jitted executors; under a USER'S outer
    ``jax.jit`` the closed-over schedule arrays are necessarily trace
    constants, so the reciprocal fold may cost the last ulp on resolved
    values there (the erasure trajectory is exact regardless).
    """

    __slots__ = ("N", "r_max", "n_erased", "n_rounds", "n_resolved",
                 "fully_resolved", "offsets", "target",
                 "idx_lo", "w_lo", "coeff_lo",
                 "idx_hi", "w_hi", "coeff_hi", "mask_key", "_ops")

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"PeelSchedule(N={self.N}, n_erased={self.n_erased}, "
                f"n_resolved={self.n_resolved}, n_rounds={self.n_rounds}, "
                f"fully_resolved={self.fully_resolved})")


def erasure_mask_key(erased) -> bytes:
    """Canonical packed-bitmask key of a concrete erasure mask — the
    schedule-cache key and the schedule/mask consistency fingerprint."""
    e = np.asarray(erased, bool)
    return np.packbits(e).tobytes()


def compile_peel_schedule(code: LDPCCode, erased) -> PeelSchedule:
    """Symbolically solve the peeling decode for ``(code, erased)``.

    Runs the flooding schedule on the erasure mask alone (host-side numpy,
    no payload arithmetic) until fixpoint and returns the
    :class:`PeelSchedule` that :func:`peel_decode` et al. replay under
    ``backend="replay"``.  Work is O(rounds · edges) once per pattern;
    every replay of the result is O(resolved edges).
    """
    if not isinstance(code, LDPCCode):
        raise ValueError(
            "compile_peel_schedule needs an LDPCCode (neighbor table); got "
            f"{type(code).__name__!r}")
    if isinstance(erased, jax.core.Tracer):
        raise ValueError(
            "compile_peel_schedule needs a CONCRETE erasure mask — the "
            "schedule is solved host-side from the pattern. Under jit, "
            "solve outside (e.g. via repro.core.schedule_cache) and pass "
            "the schedule in as a static argument.")
    idx = np.asarray(code.check_idx)          # (p, r_max), sentinel N
    coeff = np.asarray(code.check_coeff)      # (p, r_max), 0-padded
    N = int(code.N)
    e0 = np.asarray(erased, bool)
    if e0.shape != (N,):
        raise ValueError(f"erased must be ({N},); got {e0.shape}")
    e = np.zeros(N + 1, bool)
    e[:N] = e0

    offsets = [0]
    tgt_parts: list[np.ndarray] = []
    lo_parts: list[np.ndarray] = []
    hi_parts: list[np.ndarray] = []
    while True:
        ne = e[idx]                           # (p, r_max)
        rows = np.flatnonzero(ne.sum(axis=1) == 1)
        if rows.size == 0:
            break
        slot = ne[rows].argmax(axis=1)
        tgts = idx[rows, slot]
        # Per duplicate-resolved variable: lowest and highest check row
        # (``rows`` ascends, so first/last occurrence = lowest/highest).
        uniq, first = np.unique(tgts, return_index=True)
        _, first_rev = np.unique(tgts[::-1], return_index=True)
        last = tgts.size - 1 - first_rev
        tgt_parts.append(uniq.astype(np.int32))
        lo_parts.append(rows[first].astype(np.int32))
        hi_parts.append(rows[last].astype(np.int32))
        offsets.append(offsets[-1] + uniq.size)
        e[uniq] = False

    def _cat(parts):
        return (np.concatenate(parts) if parts
                else np.zeros((0,), np.int32))

    target = _cat(tgt_parts)
    n = int(target.size)
    sched = PeelSchedule.__new__(PeelSchedule)
    sched.N = N
    sched.r_max = int(idx.shape[1])
    sched.n_erased = int(e0.sum())
    sched.n_rounds = len(offsets) - 1
    sched.n_resolved = n
    sched.fully_resolved = not e[:N].any()
    sched.offsets = np.asarray(offsets, np.int32)
    sched.target = target
    for rule, rows_all in (("lo", _cat(lo_parts)), ("hi", _cat(hi_parts))):
        nidx = idx[rows_all]                  # (n, r_max)
        ncoeff = coeff[rows_all]
        tslot = (nidx == target[:, None]).argmax(axis=1)
        # Known-neighbor weights exactly as the runtime rounds compute them
        # (coeff * (1 - erased)): the target slot is the ONLY erased
        # neighbor of a firing check, so the multiply — not an overwrite —
        # preserves signed zeros bit-for-bit.
        known_f = np.ones_like(ncoeff)
        known_f[np.arange(n), tslot] = 0.0
        setattr(sched, f"idx_{rule}", nidx.astype(np.int32))
        setattr(sched, f"w_{rule}", ncoeff * known_f)
        setattr(sched, f"coeff_{rule}", ncoeff[np.arange(n), tslot])
    sched.mask_key = erasure_mask_key(e0)
    sched._ops = {}
    return sched


def _check_schedule(sched: PeelSchedule, code, erased) -> None:
    if not isinstance(sched, PeelSchedule):
        raise ValueError(f"schedule must be a PeelSchedule; got "
                         f"{type(sched).__name__!r}")
    N = code.N if isinstance(code, (LDPCCode, SeededLDPC)) else None
    if N is not None and sched.N != N:
        raise ValueError(f"schedule was solved for N={sched.N}, code has "
                         f"N={N}")
    # With a concrete mask the fingerprint check is cheap; under jit the
    # mask is a tracer and the caller (cache / driver) owns consistency.
    if not isinstance(erased, jax.core.Tracer):
        if sched.mask_key != erasure_mask_key(erased):
            raise ValueError(
                "schedule does not match the erasure mask being decoded "
                "(stale cache entry or wrong pattern)")


def _replay_rounds_used(sched: PeelSchedule, budget: int | jax.Array):
    """Round count matching the adaptive while_loop's stopping rule
    ``(d < budget) & progressed & e.any()``, from the schedule alone:
    0 if nothing was erased, else min(budget, R) when the pattern fully
    resolves in R rounds, else min(budget, R+1) — one probe round past the
    fixpoint observes no progress.  ``budget`` may be traced."""
    if sched.n_erased == 0:
        return jnp.int32(0)
    probe = sched.n_rounds + (0 if sched.fully_resolved else 1)
    b = jnp.asarray(budget, jnp.int32)
    return jnp.maximum(0, jnp.minimum(b, probe)).astype(jnp.int32)


def _sched_ops(sched: PeelSchedule, rule: str) -> tuple:
    """Per-round replay operands ``(nidx, w, coeff, target)`` as device
    arrays, built lazily once per (schedule, tie-break rule) and cached on
    the schedule.

    The executors take these as RUNTIME operands, never as jit constants:
    baked-in constants invite precision-changing folds (XLA rewrites
    divide-by-constant into multiply-by-reciprocal, breaking bit-parity
    with the flooding rounds' runtime divide), and operand-passing means
    jit specializes on segment shapes only, so recurring straggler
    patterns of the same size share one compiled executable.
    """
    ops = sched._ops.get(rule)
    if ops is None:
        off = sched.offsets
        idx = getattr(sched, f"idx_{rule}")
        w = getattr(sched, f"w_{rule}")
        cf = getattr(sched, f"coeff_{rule}")
        # ensure_compile_time_eval keeps these CONCRETE even when the
        # first use is under a caller's jit trace — otherwise jnp.asarray
        # lifts the host arrays to that trace's tracers and caching them
        # on the schedule would poison every later eager replay
        with jax.ensure_compile_time_eval():
            ops = tuple(
                (jnp.asarray(idx[s0:s1]), jnp.asarray(w[s0:s1]),
                 jnp.asarray(cf[s0:s1]), jnp.asarray(sched.target[s0:s1]))
                for s0, s1 in ((int(off[k]), int(off[k + 1]))
                               for k in range(sched.n_rounds)))
        sched._ops[rule] = ops
    return ops


def _replay_round(v, e, nidx, w, cf, tgt):
    """One replay round's arithmetic on the resolving checks only —
    exactly the flooding rounds' op sequence (:func:`_edge_sum` chain,
    then negate / guarded divide) restricted to ``len(tgt)`` rows."""
    dt = v.dtype
    v_pad = jnp.concatenate([v, jnp.zeros((1, v.shape[1]), dt)])
    nv = v_pad[nidx]                                     # (s, r_max, V)
    sums = _edge_sum(nv, w.astype(dt))
    cfd = cf.astype(dt)
    return -sums / jnp.where(cfd == 0.0, 1.0, cfd)[:, None]


@jax.jit
def _replay_fixed_ops(ops: tuple, values, erased):
    """Replay pre-sliced schedule rounds on one pattern.

    Mirrors :func:`peel_round_sparse`'s arithmetic exactly — the same
    :func:`_edge_sum` chain over the same r_max slots with the same
    pre-masked weights, restricted to the resolving checks ("high" winner
    = the duplicate scatter's last write) — so values are bit-identical
    to the sparse flooding decode while doing O(resolved edges) work with
    no while_loop or convergence mask.
    """
    v, e = values, erased
    for nidx, w, cf, tgt in ops:
        new_val = _replay_round(v, e, nidx, w, cf, tgt)
        v = v.at[tgt].set(new_val)
        e = e.at[tgt].set(False)
    return v, e


def _replay_fixed(sched: PeelSchedule, values, erased, rounds: int):
    return _replay_fixed_ops(_sched_ops(sched, "hi")[:rounds],
                             values, erased)


def _replay_slot_lo(slot_ops: tuple, v, e, budget):
    """One batch slot's replay mirroring :func:`peel_round_sparse_batch`'s
    arithmetic (the same :func:`_edge_sum` chain, "low" winner = the
    candidate scan's lowest-check-row first match).  ``budget`` is a
    traced per-slot round budget (writes beyond it are masked off — the
    state they would have read is still the correct prefix state), or
    None for the fixed-D batch decode."""
    for k, (nidx, w, cf, tgt) in enumerate(slot_ops):
        new_val = _replay_round(v, e, nidx, w, cf, tgt)
        if budget is None:
            v = v.at[tgt].set(new_val)
            e = e.at[tgt].set(False)
        else:
            apply = k < budget
            v = v.at[tgt].set(jnp.where(apply, new_val, v[tgt]))
            e = e.at[tgt].set(jnp.where(apply, False, e[tgt]))
    return v, e


@jax.jit
def _replay_batch_fixed_ops(ops_by_slot: tuple, values, erased):
    outs = [_replay_slot_lo(ops, values[b], erased[b], None)
            for b, ops in enumerate(ops_by_slot)]
    return (jnp.stack([o[0] for o in outs]),
            jnp.stack([o[1] for o in outs]))


def _replay_batch_fixed(scheds: tuple, values, erased, iters: int):
    ops = tuple(_sched_ops(s, "lo")[:min(iters, s.n_rounds)]
                for s in scheds)
    return _replay_batch_fixed_ops(ops, values, erased)


@jax.jit
def _replay_batch_adaptive_ops(ops_by_slot: tuple, values, erased, budgets):
    outs = [_replay_slot_lo(ops, values[b], erased[b], budgets[b])
            for b, ops in enumerate(ops_by_slot)]
    return (jnp.stack([o[0] for o in outs]),
            jnp.stack([o[1] for o in outs]))


def _replay_batch_adaptive(scheds: tuple, values, erased, budgets):
    ops = tuple(_sched_ops(s, "lo") for s in scheds)
    v, e = _replay_batch_adaptive_ops(ops, values, erased, budgets)
    d = jnp.stack([_replay_rounds_used(s, budgets[b])
                   for b, s in enumerate(scheds)])
    return v, e, d


def _replay_schedules(code, erased, schedules, B: int) -> tuple:
    """Per-slot schedules for the batched replay: validate the given ones
    or solve from the (necessarily concrete) per-slot masks."""
    if schedules is not None:
        scheds = tuple(schedules)
        if len(scheds) != B:
            raise ValueError(f"schedules must have length {B}; got "
                             f"{len(scheds)}")
        for b, s in enumerate(scheds):
            _check_schedule(s, code, erased[b])
        return scheds
    if isinstance(erased, jax.core.Tracer):
        raise ValueError(
            "backend='replay' under jit needs schedules= precompiled from "
            "the concrete per-slot masks (see repro.core.schedule_cache)")
    return tuple(compile_peel_schedule(code, erased[b]) for b in range(B))


# ----------------------------------------------------------------- dispatch


def _tile_knobs(code, bp, bv, vmem_budget_bytes):
    """Concrete (bp, bv) for the tiled kernels: ``bp`` sized from the VMEM
    budget unless given, ``bv`` defaulting to the kernels' 128 lanes."""
    if bp is None:
        bp = pick_tile_bp(code, vmem_budget_bytes=vmem_budget_bytes)
    return int(bp), int(bv) if bv is not None else 128


def _seeded_spec(code):
    """The hashable :class:`~repro.core.ldpc.SeededStructure` for a seeded
    code — materialized (``kind="ldpc-seeded"``), structure-only, or the
    bare structure itself (launch-layer callers hold no code object)."""
    if isinstance(code, SeededStructure):
        return code
    if isinstance(code, SeededLDPC):
        return code.structure
    return seeded_structure_of(code)


def _resolve_seeded_mode(seeded_mode: str, code, V: int, bp: int) -> str:
    """Resolve the ``seeded_mode`` knob to a concrete round implementation:
    "auto" applies the :func:`repro.core.hwcaps.pick_seeded_mode` crossover
    (gather iff the dense-tile round's modeled FLOPs exceed the platform's
    ``mxu_advantage ×`` the gather round's)."""
    if seeded_mode not in SEEDED_MODES:
        raise ValueError(f"unknown seeded_mode {seeded_mode!r}; "
                         f"want one of {SEEDED_MODES}")
    if seeded_mode == "auto":
        from repro.core.hwcaps import pick_seeded_mode

        return pick_seeded_mode(_seeded_spec(code), V, bp=bp)
    return seeded_mode


def peel_decode(
    code: LDPCCode | tuple[jax.Array, jax.Array],
    values: jax.Array,
    erased: jax.Array,
    iters: int,
    *,
    backend: str = "auto",
    bp: int | None = None,
    bv: int | None = None,
    vmem_budget_bytes: int | None = None,
    seeded_mode: str = "dense_tile",
    schedule: PeelSchedule | None = None,
) -> DecodeResult:
    """Run exactly ``iters`` flooding rounds (the paper's fixed-D decode).

    ``backend`` selects the implementation — see the module docstring for
    the full matrix.  The default ``"auto"`` keeps small/tuple inputs on the
    dense reference and routes large codes to the sparse neighbor-table
    round (or, on TPU, the fused one-kernel Pallas decode — resident H
    within ``vmem_budget_bytes``, check-axis tiled beyond it).  ``bp`` /
    ``bv`` are the tiled kernels' check/payload tile knobs (``bp`` defaults
    to :func:`pick_tile_bp`'s budget-sized tile).  ``seeded_mode``
    sub-dispatches the "pallas_seeded" round — "dense_tile" | "gather" |
    "auto" (hwcaps crossover); ignored by other backends.  ``schedule``
    feeds ``backend="replay"`` a pre-solved :class:`PeelSchedule` (e.g. a
    :mod:`repro.core.schedule_cache` hit); without it the pattern is
    solved on the fly, which requires a concrete ``erased``.
    """
    backend = resolve_backend(backend, code,
                              vmem_budget_bytes=vmem_budget_bytes)
    if schedule is not None and backend != "replay":
        raise ValueError("schedule= is only meaningful with "
                         "backend='replay'")
    v, squeeze = _expand(jnp.asarray(values))
    e = jnp.asarray(erased, bool)
    iters = int(iters)
    if backend == "replay":
        sched = (schedule if schedule is not None
                 else compile_peel_schedule(code, e))
        _check_schedule(sched, code, e)
        v, e = _replay_fixed(sched, v, e, min(iters, sched.n_rounds))
    elif backend == "sparse":
        idx, coeff = _tables(code)
        v, e = peel_fixed_sparse(idx, coeff, v, e, iters)
    elif backend == "pallas":
        from repro.kernels.ldpc_peel import peel_decode_pallas

        H = jnp.asarray(code.H, _float_dtype(v.dtype))
        v, e = peel_decode_pallas(H, v, e, iters)
    elif backend == "pallas_tiled":
        from repro.kernels.ldpc_peel import peel_decode_tiled_pallas

        bp_, bv_ = _tile_knobs(code, bp, bv, vmem_budget_bytes)
        H = jnp.asarray(code.H, _float_dtype(v.dtype))
        v, e = peel_decode_tiled_pallas(H, v, e, iters, bp=bp_, bv=bv_)
    elif backend == "pallas_seeded":
        from repro.kernels.ldpc_peel import peel_decode_seeded_pallas

        bp_, bv_ = _tile_knobs(code, bp, bv, vmem_budget_bytes)
        mode = _resolve_seeded_mode(seeded_mode, code, v.shape[1], bp_)
        v, e = peel_decode_seeded_pallas(_seeded_spec(code), v, e, iters,
                                         bp=bp_, bv=bv_, mode=mode)
    else:
        H, Hb = _mats(code, v.dtype)
        v, e = peel_fixed_dense(H, Hb, v, e, iters)
    if squeeze:
        v = v[:, 0]
    return DecodeResult(v, e, jnp.int32(iters))


# ------------------------------------------------------------- batched axis


@partial(jax.jit, static_argnames=("iters",))
def _peel_fixed_dense_batch(H, Hb, values, erased, iters: int):
    # vmap the whole fixed-D loop; H/Hb broadcast (loaded once, shared) and
    # the per-round matvecs batch into (p, N) @ (N, B) GEMMs.
    return jax.vmap(lambda v, e: peel_fixed_dense(H, Hb, v, e, iters))(
        values, erased)


def peel_round_sparse_batch(check_idx, check_coeff, var_idx, vb, eb):
    """One flooding round for B independent erasure patterns, scatter-free.

    Batch-minor layout: ``vb (N+1, B, V)`` values (one zero sentinel row,
    V payload lanes per pattern), ``eb (N+1, B)`` f32 0/1 erasure flags —
    neighbor gathers then move contiguous rows instead of strided scalars.

    Check side: a solvable check has EXACTLY one erased neighbour, so the
    masked sums ``Σ idx·e`` / ``Σ coeff·e`` *are* its resolved index and
    coefficient — exact in f32 (small integers / single surviving term), no
    argmax, and bit-identical solvability decisions to
    :func:`peel_round_sparse`.  The V payload lanes of one pattern share a
    trajectory, so ALL structure work (cnt/pos/coeff, solvability, the
    candidate-match masks) is computed ONCE per pattern on the ``(·, B)``
    erasure flags and broadcast over V — only the value sums and the
    resolved-value writes touch the ``(·, B, V)`` payload.

    Variable side: XLA's scatter is the slow op on CPU (~70 ns/element,
    serialized); instead each variable GATHERS its ≤ l_max candidate
    resolutions through the column table ``var_idx (N, l_max)``
    (:attr:`LDPCCode.var_idx`) and keeps the lowest-row match.  Checks that
    resolve the same coordinate write consistent values (parity checks of
    one codeword), so the choice only pins f32 rounding.
    """
    N = vb.shape[0] - 1
    dt = vb.dtype
    ne = eb[check_idx]                              # (p, r_max, B)
    nv = vb[check_idx]                              # (p, r_max, B, V)
    cnt = ne.sum(axis=1)                            # (p, B) — exact counts
    c3 = check_coeff.astype(dt)[:, :, None]
    known = (1.0 - ne) * c3                         # (p, r_max, B)
    sums = _edge_sum(nv, known)                     # (p, B, V)
    posf = (check_idx.astype(dt)[:, :, None] * ne).sum(axis=1)
    coeff = (c3 * ne).sum(axis=1)                   # (p, B)
    solvable = cnt == 1.0
    new_val = -sums / jnp.where(coeff == 0.0, 1.0, coeff)[..., None]
    res_pos = jnp.where(solvable, posf.astype(jnp.int32), N)    # (p, B)

    B, V = vb.shape[1], vb.shape[2]
    rp_pad = jnp.concatenate([res_pos, jnp.full((1, B), N, jnp.int32)])
    nv_pad = jnp.concatenate([new_val, jnp.zeros((1, B, V), dt)])
    cand_pos = rp_pad[var_idx]                      # (N, l_max, B)
    cand_val = nv_pad[var_idx]                      # (N, l_max, B, V)
    me = jax.lax.broadcasted_iota(jnp.int32, cand_pos.shape, 0)
    match = cand_pos == me                          # (N, l_max, B)
    resolved = jnp.zeros((N, B), bool)
    val = jnp.zeros((N, B, V), dt)
    for t in range(match.shape[1]):                 # l_max is small & static
        m = match[:, t]
        val = jnp.where((m & ~resolved)[..., None], cand_val[:, t], val)
        resolved = resolved | m
    vb = vb.at[:N].set(jnp.where(resolved[..., None], val, vb[:N]))
    eb = eb.at[:N].set(jnp.where(resolved, 0.0, eb[:N]))
    return vb, eb


@partial(jax.jit, static_argnames=("iters",))
def _peel_fixed_sparse_batch(check_idx, check_coeff, var_idx, values, erased,
                             iters: int):
    """values (B, N, V), erased (B, N) → fixed-D batch-major sparse decode.

    The erasure state is carried once per pattern (``(N+1, B)``) while the
    payload keeps its own V axis (``(N+1, B, V)``), so the check-side
    structure work runs once per pattern and only the value arithmetic
    scales with V — see :func:`peel_round_sparse_batch`.
    """
    B, N, V = values.shape
    vb = jnp.concatenate([jnp.transpose(values, (1, 0, 2)),
                          jnp.zeros((1, B, V), values.dtype)])  # (N+1, B, V)
    eb = jnp.concatenate([erased.T.astype(values.dtype),
                          jnp.zeros((1, B), values.dtype)])     # (N+1, B)

    def body(_, carry):
        return peel_round_sparse_batch(check_idx, check_coeff, var_idx,
                                       *carry)

    vb, eb = jax.lax.fori_loop(0, iters, body, (vb, eb))
    out_v = jnp.transpose(vb[:N], (1, 0, 2))
    out_e = eb[:N].T > 0.0
    return out_v, out_e


def peel_decode_batch(
    code: LDPCCode | tuple[jax.Array, jax.Array],
    values: jax.Array,
    erased: jax.Array,
    iters: int,
    *,
    backend: str = "auto",
    bp: int | None = None,
    bv: int | None = None,
    vmem_budget_bytes: int | None = None,
    seeded_mode: str = "dense_tile",
    schedules=None,
) -> DecodeResult:
    """Decode ``B`` INDEPENDENT erasure patterns in one launch.

    ``values`` is ``(B, N)`` or ``(B, N, V)``; ``erased`` is ``(B, N)``
    bool — one straggler realization per batch element.  Each element is
    decoded exactly as :func:`peel_decode` would decode it alone (identical
    trajectories); the batch axis only amortizes dispatch and keeps the
    code's structure (H / neighbor table) loaded once:

    * "dense" / "sparse": the fixed-D loop is ``vmap``-ed over the pattern
      axis with the code operands broadcast;
    * "pallas": ``peel_decode_batch_pallas`` — ONE ``pallas_call`` whose
      grid runs over the batch with the H tile resident in VMEM and shared;
    * "pallas_tiled": ``peel_decode_batch_tiled_pallas`` — one launch, H
      streamed over check tiles per slot (beyond the VMEM cap);
    * "replay": per-slot pre-solved schedules (``schedules=``, one
      :class:`PeelSchedule` per slot, or solved on the fly from concrete
      masks) replayed as straight-line gather/FMA work.

    This is the serving primitive: many concurrent coded matvec/gradient
    queries, each with its own straggler mask, one decode launch
    (see :mod:`repro.serving.coded_queries`).
    """
    backend = resolve_backend(backend, code,
                              vmem_budget_bytes=vmem_budget_bytes)
    if schedules is not None and backend != "replay":
        raise ValueError("schedules= is only meaningful with "
                         "backend='replay'")
    v = jnp.asarray(values)
    if v.ndim not in (2, 3):
        raise ValueError(f"batched values must be (B, N) or (B, N, V); "
                         f"got shape {v.shape}")
    squeeze = v.ndim == 2
    if squeeze:
        v = v[:, :, None]
    e = jnp.asarray(erased, bool)
    iters = int(iters)
    if backend == "replay":
        scheds = _replay_schedules(code, e, schedules, v.shape[0])
        v, e = _replay_batch_fixed(scheds, v, e, iters)
    elif backend == "sparse":
        idx, coeff = _tables(code)
        v, e = _peel_fixed_sparse_batch(idx, coeff,
                                        jnp.asarray(code.var_idx), v, e,
                                        iters)
    elif backend == "pallas":
        from repro.kernels.ldpc_peel import peel_decode_batch_pallas

        H = jnp.asarray(code.H, _float_dtype(v.dtype))
        v, e = peel_decode_batch_pallas(H, v, e, iters)
    elif backend == "pallas_tiled":
        from repro.kernels.ldpc_peel import peel_decode_batch_tiled_pallas

        bp_, bv_ = _tile_knobs(code, bp, bv, vmem_budget_bytes)
        H = jnp.asarray(code.H, _float_dtype(v.dtype))
        v, e = peel_decode_batch_tiled_pallas(H, v, e, iters, bp=bp_, bv=bv_)
    elif backend == "pallas_seeded":
        from repro.kernels.ldpc_peel import peel_decode_batch_seeded_pallas

        bp_, bv_ = _tile_knobs(code, bp, bv, vmem_budget_bytes)
        mode = _resolve_seeded_mode(seeded_mode, code, v.shape[2], bp_)
        v, e = peel_decode_batch_seeded_pallas(_seeded_spec(code), v, e,
                                               iters, bp=bp_, bv=bv_,
                                               mode=mode)
    else:
        H, Hb = _mats(code, v.dtype)
        v, e = _peel_fixed_dense_batch(H, Hb, v, e, iters)
    if squeeze:
        v = v[:, :, 0]
    return DecodeResult(v, e, jnp.int32(iters))


# ----------------------------------------------------------------- adaptive


@partial(jax.jit, static_argnames=("max_iters",))
def _peel_adaptive(H, Hb, values, erased, max_iters: int):
    def cond(carry):
        _, e, d, progressed = carry
        return (d < max_iters) & progressed & e.any()

    def body(carry):
        v, e, d, _ = carry
        v2, e2 = peel_round(H, Hb, v, e)
        return v2, e2, d + 1, (e2 != e).any()

    v, e, d, _ = jax.lax.while_loop(
        cond, body, (values, erased, jnp.int32(0), jnp.bool_(True))
    )
    return v, e, d


@partial(jax.jit, static_argnames=("max_iters",))
def _peel_adaptive_sparse(check_idx, check_coeff, values, erased, max_iters: int):
    def cond(carry):
        _, e, d, progressed = carry
        return (d < max_iters) & progressed & e.any()

    def body(carry):
        v, e, d, _ = carry
        v2, e2 = peel_round_sparse(check_idx, check_coeff, v, e)
        return v2, e2, d + 1, (e2 != e).any()

    v, e, d, _ = jax.lax.while_loop(
        cond, body, (values, erased, jnp.int32(0), jnp.bool_(True))
    )
    return v, e, d


def peel_decode_adaptive(
    code: LDPCCode | tuple[jax.Array, jax.Array],
    values: jax.Array,
    erased: jax.Array,
    max_iters: int | None = None,
    *,
    backend: str = "auto",
    bp: int | None = None,
    bv: int | None = None,
    vmem_budget_bytes: int | None = None,
    seeded_mode: str = "dense_tile",
    schedule: PeelSchedule | None = None,
) -> DecodeResult:
    """Decode until fixpoint (no check resolves) or ``max_iters`` rounds.

    This is the "decoding effort adapts to the number of stragglers" mode:
    with few erasures the loop exits after 1-2 rounds.  ``backend="pallas"``
    runs the early-exit loop INSIDE the fused kernel (one launch, in-kernel
    while_loop on the unresolved count) — same trajectory and round count as
    the dense/sparse while_loops; ``"pallas_tiled"`` additionally stops the
    H streaming at the early exit.  ``backend="replay"`` already knows the
    fixpoint from the schedule, so "adaptivity" costs nothing: the replay
    is sliced to ``min(max_iters, R)`` rounds and the round count is
    computed from the schedule, matching the while_loop's stopping rule
    (including the one probe round a non-fully-resolving pattern pays).
    """
    backend = resolve_backend(backend, code, adaptive=True,
                              vmem_budget_bytes=vmem_budget_bytes)
    if schedule is not None and backend != "replay":
        raise ValueError("schedule= is only meaningful with "
                         "backend='replay'")
    if max_iters is None:
        max_iters = int(code.N if isinstance(code, (LDPCCode, SeededLDPC))
                        else code[0].shape[1])
    v, squeeze = _expand(jnp.asarray(values))
    e = jnp.asarray(erased, bool)
    if backend == "replay":
        sched = (schedule if schedule is not None
                 else compile_peel_schedule(code, e))
        _check_schedule(sched, code, e)
        v, e = _replay_fixed(sched, v, e,
                             min(int(max_iters), sched.n_rounds))
        d = _replay_rounds_used(sched, int(max_iters))
    elif backend == "sparse":
        idx, coeff = _tables(code)
        v, e, d = _peel_adaptive_sparse(idx, coeff, v, e, int(max_iters))
    elif backend == "pallas":
        from repro.kernels.ldpc_peel import peel_decode_adaptive_pallas

        H = jnp.asarray(code.H, _float_dtype(v.dtype))
        v, e, d = peel_decode_adaptive_pallas(H, v, e, int(max_iters))
    elif backend == "pallas_tiled":
        from repro.kernels.ldpc_peel import peel_decode_adaptive_tiled_pallas

        bp_, bv_ = _tile_knobs(code, bp, bv, vmem_budget_bytes)
        H = jnp.asarray(code.H, _float_dtype(v.dtype))
        v, e, d = peel_decode_adaptive_tiled_pallas(H, v, e, int(max_iters),
                                                    bp=bp_, bv=bv_)
    elif backend == "pallas_seeded":
        from repro.kernels.ldpc_peel import peel_decode_adaptive_seeded_pallas

        bp_, bv_ = _tile_knobs(code, bp, bv, vmem_budget_bytes)
        mode = _resolve_seeded_mode(seeded_mode, code, v.shape[1], bp_)
        v, e, d = peel_decode_adaptive_seeded_pallas(
            _seeded_spec(code), v, e, int(max_iters), bp=bp_, bv=bv_,
            mode=mode)
    else:
        H, Hb = _mats(code, v.dtype)
        v, e, d = _peel_adaptive(H, Hb, v, e, int(max_iters))
    if squeeze:
        v = v[:, 0]
    return DecodeResult(v, e, d)


# -------------------------------------------------- batched x adaptive axis


@jax.jit
def _peel_adaptive_dense_batch(H, Hb, values, erased, budgets):
    """Per-slot early-exit dense decode: vmap of the adaptive while_loop.

    JAX's while_loop batching rule gives exactly the per-slot semantics: the
    lowered loop runs while ANY slot's predicate holds, and a slot whose own
    predicate is false has its carry frozen via select — so each slot's
    (values, erased, rounds) trajectory is the one the sequential adaptive
    decode produces under its own ``budgets[b]`` round budget.
    """
    def one(v, e, budget):
        def cond(carry):
            _, e_, d, progressed = carry
            return (d < budget) & progressed & e_.any()

        def body(carry):
            v_, e_, d, _ = carry
            v2, e2 = peel_round(H, Hb, v_, e_)
            return v2, e2, d + 1, (e2 != e_).any()

        return jax.lax.while_loop(
            cond, body, (v, e, jnp.int32(0), jnp.bool_(True)))[:3]

    return jax.vmap(one)(values, erased, budgets)


@jax.jit
def _peel_adaptive_sparse_batch(check_idx, check_coeff, var_idx, values,
                                erased, budgets):
    """Per-slot early-exit decode on the scatter-free batch-major round.

    One while_loop advances ALL still-active slots a round at a time; a
    per-slot active mask ``(d < budget) & progressed & any_erased`` freezes
    converged slots' columns (select — their lanes carry no further work or
    rounding churn) and the loop exits as soon as every slot is done, so a
    batch of light stragglers costs 1-2 rounds regardless of the budget.
    Layout and round semantics are exactly :func:`peel_round_sparse_batch`'s
    (values (B, N, V), erased (B, N) bool; the V lanes of one slot share
    the trajectory, and all structure work runs once per slot).  Returns
    (values, erased, rounds (B,)).
    """
    B, N, V = values.shape
    dt = values.dtype
    vb = jnp.concatenate([jnp.transpose(values, (1, 0, 2)),
                          jnp.zeros((1, B, V), dt)])         # (N+1, B, V)
    eb = jnp.concatenate([erased.T.astype(dt),
                          jnp.zeros((1, B), dt)])            # (N+1, B)
    budgets = budgets.astype(jnp.int32)

    def slot_erased_any(eb_):
        return eb_[:N].sum(axis=0) > 0.0                     # (B,) bool

    # The per-slot predicate ``(d < budget) & progressed & any_erased`` is
    # carried as one ACTIVE mask (slots only ever deactivate), so each round
    # costs exactly one masked-round + two (N, B) reductions — the cond is a
    # free ``active.any()``.
    def cond(carry):
        return carry[3].any()

    def body(carry):
        vb_, eb_, d, active = carry
        vb2, eb2 = peel_round_sparse_batch(check_idx, check_coeff, var_idx,
                                           vb_, eb_)
        changed = (eb2[:N] != eb_[:N]).any(axis=0)           # (B,)
        vb_ = jnp.where(active[None, :, None], vb2, vb_)
        eb_ = jnp.where(active[None, :], eb2, eb_)
        d = jnp.where(active, d + 1, d)
        active = (active & (d < budgets) & changed
                  & slot_erased_any(eb_))
        return vb_, eb_, d, active

    active0 = (budgets > 0) & slot_erased_any(eb)
    vb, eb, d, _ = jax.lax.while_loop(
        cond, body, (vb, eb, jnp.zeros((B,), jnp.int32), active0))
    out_v = jnp.transpose(vb[:N], (1, 0, 2))
    out_e = eb[:N].T > 0.0
    return out_v, out_e, d


def peel_decode_batch_adaptive(
    code: LDPCCode | tuple[jax.Array, jax.Array],
    values: jax.Array,
    erased: jax.Array,
    max_iters: int | None = None,
    *,
    backend: str = "auto",
    budgets: jax.Array | None = None,
    bp: int | None = None,
    bv: int | None = None,
    vmem_budget_bytes: int | None = None,
    seeded_mode: str = "dense_tile",
    schedules=None,
) -> DecodeResult:
    """Decode ``B`` independent patterns with PER-SLOT early exit, one launch.

    The batched form of :func:`peel_decode_adaptive`: every slot follows its
    own stopping rule (no progress, nothing erased, or its round budget
    exhausted) and reports its own round count — ``rounds_used`` is the
    per-slot ``(B,) int32`` vector.  A slot full of light stragglers stops
    after 1-2 rounds while a heavy slot keeps peeling; no slot's trajectory
    depends on any other slot's.  Trajectory parity with the sequential
    adaptive decode is exact (same erasure masks and round counts,
    bit-for-bit); values agree up to f32 summation order, as on the fixed-D
    batch axis.

    ``budgets`` optionally gives each slot its own round budget
    ``(B,) int`` — a TRACED operand (varying budgets launch-to-launch never
    recompiles), clamped nowhere: a slot with budget 0 is returned
    untouched with 0 rounds.  Without it every slot gets ``max_iters``
    (default ``N``).  This is the primitive behind continuous-admission
    serving (:mod:`repro.serving.coded_queries`): in-flight slots carry
    their remaining budgets across chunked launches.

    ``backend="replay"`` takes per-slot pre-solved ``schedules=`` (or
    solves them from concrete masks); budgets stay traced — writes past a
    slot's budget are masked off and the per-slot round counts come from
    the schedules.
    """
    backend = resolve_backend(backend, code, adaptive=True,
                              vmem_budget_bytes=vmem_budget_bytes)
    if schedules is not None and backend != "replay":
        raise ValueError("schedules= is only meaningful with "
                         "backend='replay'")
    v = jnp.asarray(values)
    if v.ndim not in (2, 3):
        raise ValueError(f"batched values must be (B, N) or (B, N, V); "
                         f"got shape {v.shape}")
    squeeze = v.ndim == 2
    if squeeze:
        v = v[:, :, None]
    e = jnp.asarray(erased, bool)
    B = v.shape[0]
    if max_iters is None:
        max_iters = int(code.N if isinstance(code, (LDPCCode, SeededLDPC))
                        else code[0].shape[1])
    if budgets is None:
        budgets = jnp.full((B,), int(max_iters), jnp.int32)
    else:
        budgets = jnp.asarray(budgets, jnp.int32)
        if budgets.shape != (B,):
            raise ValueError(f"budgets must be ({B},); got {budgets.shape}")
    if backend == "replay":
        scheds = _replay_schedules(code, e, schedules, B)
        v, e, d = _replay_batch_adaptive(scheds, v, e, budgets)
    elif backend == "sparse":
        idx, coeff = _tables(code)
        v, e, d = _peel_adaptive_sparse_batch(idx, coeff,
                                              jnp.asarray(code.var_idx),
                                              v, e, budgets)
    elif backend == "pallas":
        from repro.kernels.ldpc_peel import peel_decode_batch_adaptive_pallas

        H = jnp.asarray(code.H, _float_dtype(v.dtype))
        v, e, d = peel_decode_batch_adaptive_pallas(H, v, e, budgets)
    elif backend == "pallas_tiled":
        from repro.kernels.ldpc_peel import (
            peel_decode_batch_adaptive_tiled_pallas)

        bp_, bv_ = _tile_knobs(code, bp, bv, vmem_budget_bytes)
        H = jnp.asarray(code.H, _float_dtype(v.dtype))
        v, e, d = peel_decode_batch_adaptive_tiled_pallas(H, v, e, budgets,
                                                          bp=bp_, bv=bv_)
    elif backend == "pallas_seeded":
        from repro.kernels.ldpc_peel import (
            peel_decode_batch_adaptive_seeded_pallas)

        bp_, bv_ = _tile_knobs(code, bp, bv, vmem_budget_bytes)
        mode = _resolve_seeded_mode(seeded_mode, code, v.shape[2], bp_)
        v, e, d = peel_decode_batch_adaptive_seeded_pallas(
            _seeded_spec(code), v, e, budgets, bp=bp_, bv=bv_, mode=mode)
    else:
        H, Hb = _mats(code, v.dtype)
        v, e, d = _peel_adaptive_dense_batch(H, Hb, v, e, budgets)
    if squeeze:
        v = v[:, :, 0]
    return DecodeResult(v, e, d)


def erased_after(code: LDPCCode, erased: np.ndarray, iters: int) -> np.ndarray:
    """Structure-only decode: which coordinates remain erased after D rounds.

    Used by tests and by the density-evolution comparison; does not touch the
    payload values.
    """
    dummy = jnp.zeros((code.N,), jnp.float32)
    res = peel_decode(code, dummy, jnp.asarray(erased, bool), iters)
    return np.asarray(res.erased)


def _float_dtype(dtype):
    return dtype if jnp.issubdtype(dtype, jnp.floating) else jnp.float32


def _mats(code, dtype) -> tuple[jax.Array, jax.Array]:
    if isinstance(code, LDPCCode):
        H = jnp.asarray(code.H, dtype=_float_dtype(dtype))
        Hb = jnp.asarray(code.H_mask)
    else:
        H, Hb = code
        H = jnp.asarray(H)
        Hb = jnp.asarray(Hb, bool)
    return H, Hb


def _tables(code: LDPCCode) -> tuple[jax.Array, jax.Array]:
    return jnp.asarray(code.check_idx), jnp.asarray(code.check_coeff)
