"""Iterative (peeling) erasure decoder for real-valued LDPC codes, in JAX.

The classic peeling decoder resolves degree-1 checks one at a time.  On TPU
we use the equivalent *flooding* schedule: in each round, every parity check
with exactly one erased neighbour resolves that neighbour.  The fixed number
of rounds ``D`` is exactly the paper's decoding-iteration knob — the quality
of the recovered gradient is monotone in ``D`` (Remark 3).

Backend matrix (``backend=`` on :func:`peel_decode` /
:func:`peel_decode_adaptive`):

=========  ==================================================================
backend    what runs
=========  ==================================================================
"dense"    the original reference: three dense ``H``-structured ops per
           round (mask matvec, matmul, argmax) — O(p·N·V) work.  Always
           available, including for raw ``(H, Hb)`` tuples.
"sparse"   gathers over the code's padded neighbor table
           (``LDPCCode.check_idx`` / ``check_coeff``) — O(p·r_max·V) work,
           i.e. proportional to the Tanner-graph edge count, the complexity
           the paper's low-cost-decoding argument assumes.  Requires an
           :class:`LDPCCode` (the table is built at construction).
"pallas"   the fused one-kernel decode
           (:func:`repro.kernels.ldpc_peel.peel_decode_pallas`): the whole
           fixed-``D`` loop runs inside a single ``pallas_call`` with ``H``
           resident in VMEM — no per-round kernel relaunch or re-padding.
           Fixed-``D`` only; ``peel_decode_adaptive`` maps it to "sparse".
           Runs in interpret mode off-TPU (correct but not fast on CPU).
"auto"     "dense" for raw tuples and small codes (N < 256); "sparse" for
           large codes off-TPU; "pallas" on TPU when the kernel's whole
           working set fits comfortably in VMEM (N ≤ 512), else "sparse".
=========  ==================================================================

All backends follow bit-identical erasure trajectories (solvability is an
exact count of erased neighbours, and every backend resolves the same
first-erased-column neighbour per check); decoded values agree up to f32
summation order.

The decoder is fully ``jit``-able (fixed ``D`` → ``lax.fori_loop``;
adaptive → ``lax.while_loop`` with early exit) and batched over symbol
payloads: ``values`` may be ``(N,)`` scalars (the paper's inner products) or
``(N, V)`` vectors (coded gradient aggregation, where each symbol is a chunk
of a partial gradient).

Erased coordinates that remain unresolved are left as-is in ``values`` but
flagged in the returned mask; callers zero-fill per the paper's Scheme 2
(both ``ĉ`` and ``b̂`` are zeroed on the unresolved set so the estimate stays
an unbiased scaled gradient — Lemma 1).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ldpc import LDPCCode

__all__ = [
    "DecodeResult",
    "peel_round",
    "peel_round_sparse",
    "peel_decode",
    "peel_decode_adaptive",
    "erased_after",
    "resolve_backend",
]

BACKENDS = ("auto", "dense", "sparse", "pallas")

# "auto" picks the sparse neighbor-table round once the dense round's O(p·N)
# work clearly loses to O(p·r_max) gathers; below this the dense matmul's
# better vectorization wins on CPU.
_AUTO_SPARSE_MIN_N = 256
# Largest N "auto" routes to the fused kernel on TPU.  The kernel's live
# VMEM working set is several (p, N) buffers (H plus mask/iota/one-hot
# temporaries), not just the H tile, so stay well inside the ~16 MiB/core
# budget: N = 512 → p·N f32 ≈ 0.5 MiB per buffer.  Larger codes use the
# sparse round until the kernel tiles H over the check axis (ROADMAP).
_AUTO_PALLAS_MAX_N = 512


class DecodeResult(NamedTuple):
    values: jax.Array  # (N,) or (N, V); decoded where possible
    erased: jax.Array  # (N,) bool; True where still unresolved
    rounds_used: jax.Array  # () int32 (== D for fixed-D decode)


def _expand(values: jax.Array) -> tuple[jax.Array, bool]:
    if values.ndim == 1:
        return values[:, None], True
    return values, False


def resolve_backend(backend: str, code, *, adaptive: bool = False) -> str:
    """Resolve the ``backend=`` knob to a concrete decode implementation.

    See the module docstring for the matrix.  Raises on unknown names and on
    sparse/pallas requests for raw ``(H, Hb)`` tuples (no neighbor table).
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown decode backend {backend!r}; want one of {BACKENDS}")
    is_code = isinstance(code, LDPCCode)
    if backend == "auto":
        if not is_code:
            return "dense"
        N = code.N
        if jax.default_backend() == "tpu":
            backend = "pallas" if N <= _AUTO_PALLAS_MAX_N else "sparse"
        else:
            backend = "sparse" if N >= _AUTO_SPARSE_MIN_N else "dense"
    if backend in ("sparse", "pallas") and not is_code:
        raise ValueError(
            f"backend={backend!r} needs an LDPCCode (neighbor table); "
            "raw (H, Hb) tuples only support backend='dense'"
        )
    if adaptive and backend == "pallas":
        # The fused kernel is fixed-D by construction; the adaptive
        # early-exit decode uses the sparse round instead.
        backend = "sparse"
    return backend


# --------------------------------------------------------------- dense round


def peel_round(
    H: jax.Array, Hb: jax.Array, values: jax.Array, erased: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """One flooding round (dense). values: (N, V), erased: (N,) bool.

    For every check row with exactly one erased neighbour ``j``:
      ``c_j = -(sum_{j' known} H[i, j'] c_{j'}) / H[i, j]``.
    Rows that resolve the same coordinate write consistent values (they are
    parity checks of the same codeword), so duplicate scatters are benign.
    """
    N = values.shape[0]
    e = erased.astype(H.dtype)  # (N,)
    cnt = Hb.astype(H.dtype) @ e  # (p,) number of erased neighbours per check
    solvable = cnt == 1.0  # (p,)
    known = values * (1.0 - e)[:, None]  # zero out erased entries
    row_sums = H @ known  # (p, V)
    # The (unique) erased neighbour of each row; arbitrary for non-solvable rows.
    pos = jnp.argmax(Hb & erased[None, :], axis=1)  # (p,)
    coeff = jnp.take_along_axis(H, pos[:, None], axis=1)[:, 0]  # (p,)
    new_val = -row_sums / jnp.where(coeff == 0.0, 1.0, coeff)[:, None]
    # Out-of-bounds scatter with mode="drop" discards non-solvable rows.
    safe_pos = jnp.where(solvable, pos, N)
    values = values.at[safe_pos].set(new_val, mode="drop")
    erased = erased.at[safe_pos].set(False, mode="drop")
    return values, erased


@partial(jax.jit, static_argnames=("iters",))
def _peel_fixed(H, Hb, values, erased, iters: int):
    def body(_, carry):
        v, e = carry
        return peel_round(H, Hb, v, e)

    values, erased = jax.lax.fori_loop(0, iters, body, (values, erased))
    return values, erased


# -------------------------------------------------------------- sparse round


def peel_round_sparse(
    check_idx: jax.Array,
    check_coeff: jax.Array,
    values: jax.Array,
    erased: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """One flooding round via neighbor-table gathers — O(p·r_max·V) work.

    ``check_idx (p, r_max) int32`` holds each check's neighbour columns in
    ascending order, padded with the sentinel ``N``; ``check_coeff`` the
    matching edge weights, padded with 0.  Gathers read from ``values`` /
    ``erased`` padded by one sentinel row, so padding slots contribute
    nothing and no branching is needed.  Semantics match :func:`peel_round`
    exactly: same solvability decisions, same resolved neighbour per check.
    """
    N = values.shape[0]
    dt = values.dtype
    e_pad = jnp.concatenate([erased, jnp.zeros((1,), erased.dtype)])  # (N+1,)
    v_pad = jnp.concatenate([values, jnp.zeros((1, values.shape[1]), dt)])
    ne = e_pad[check_idx]  # (p, r_max) bool — erased neighbours
    nef = ne.astype(dt)
    cnt = nef.sum(axis=1)  # (p,)
    nv = v_pad[check_idx]  # (p, r_max, V)
    # Known-neighbour contribution: coeff * value, erased slots zeroed.
    sums = jnp.einsum("prv,pr->pv", nv, check_coeff.astype(dt) * (1.0 - nef))
    # First erased neighbour slot (ascending column order == dense argmax).
    slot = jnp.argmax(ne, axis=1)  # (p,)
    pos = jnp.take_along_axis(check_idx, slot[:, None], axis=1)[:, 0]
    coeff = jnp.take_along_axis(check_coeff, slot[:, None], axis=1)[:, 0].astype(dt)
    solvable = cnt == 1.0
    new_val = -sums / jnp.where(coeff == 0.0, 1.0, coeff)[:, None]
    safe_pos = jnp.where(solvable, pos, N)
    values = values.at[safe_pos].set(new_val, mode="drop")
    erased = erased.at[safe_pos].set(False, mode="drop")
    return values, erased


@partial(jax.jit, static_argnames=("iters",))
def _peel_fixed_sparse(check_idx, check_coeff, values, erased, iters: int):
    def body(_, carry):
        v, e = carry
        return peel_round_sparse(check_idx, check_coeff, v, e)

    values, erased = jax.lax.fori_loop(0, iters, body, (values, erased))
    return values, erased


# ----------------------------------------------------------------- dispatch


def peel_decode(
    code: LDPCCode | tuple[jax.Array, jax.Array],
    values: jax.Array,
    erased: jax.Array,
    iters: int,
    *,
    backend: str = "auto",
) -> DecodeResult:
    """Run exactly ``iters`` flooding rounds (the paper's fixed-D decode).

    ``backend`` selects the implementation — see the module docstring for
    the full matrix.  The default ``"auto"`` keeps small/tuple inputs on the
    dense reference and routes large codes to the sparse neighbor-table
    round (or, on TPU, the fused one-kernel Pallas decode).
    """
    backend = resolve_backend(backend, code)
    v, squeeze = _expand(jnp.asarray(values))
    e = jnp.asarray(erased, bool)
    iters = int(iters)
    if backend == "sparse":
        idx, coeff = _tables(code)
        v, e = _peel_fixed_sparse(idx, coeff, v, e, iters)
    elif backend == "pallas":
        from repro.kernels.ldpc_peel import peel_decode_pallas

        H = jnp.asarray(code.H, _float_dtype(v.dtype))
        v, e = peel_decode_pallas(H, v, e, iters)
    else:
        H, Hb = _mats(code, v.dtype)
        v, e = _peel_fixed(H, Hb, v, e, iters)
    if squeeze:
        v = v[:, 0]
    return DecodeResult(v, e, jnp.int32(iters))


@partial(jax.jit, static_argnames=("max_iters",))
def _peel_adaptive(H, Hb, values, erased, max_iters: int):
    def cond(carry):
        _, e, d, progressed = carry
        return (d < max_iters) & progressed & e.any()

    def body(carry):
        v, e, d, _ = carry
        v2, e2 = peel_round(H, Hb, v, e)
        return v2, e2, d + 1, (e2 != e).any()

    v, e, d, _ = jax.lax.while_loop(
        cond, body, (values, erased, jnp.int32(0), jnp.bool_(True))
    )
    return v, e, d


@partial(jax.jit, static_argnames=("max_iters",))
def _peel_adaptive_sparse(check_idx, check_coeff, values, erased, max_iters: int):
    def cond(carry):
        _, e, d, progressed = carry
        return (d < max_iters) & progressed & e.any()

    def body(carry):
        v, e, d, _ = carry
        v2, e2 = peel_round_sparse(check_idx, check_coeff, v, e)
        return v2, e2, d + 1, (e2 != e).any()

    v, e, d, _ = jax.lax.while_loop(
        cond, body, (values, erased, jnp.int32(0), jnp.bool_(True))
    )
    return v, e, d


def peel_decode_adaptive(
    code: LDPCCode | tuple[jax.Array, jax.Array],
    values: jax.Array,
    erased: jax.Array,
    max_iters: int | None = None,
    *,
    backend: str = "auto",
) -> DecodeResult:
    """Decode until fixpoint (no check resolves) or ``max_iters`` rounds.

    This is the "decoding effort adapts to the number of stragglers" mode:
    with few erasures the loop exits after 1-2 rounds.  ``backend="pallas"``
    falls back to "sparse" (the fused kernel is fixed-D only).
    """
    backend = resolve_backend(backend, code, adaptive=True)
    if max_iters is None:
        max_iters = int(code.N if isinstance(code, LDPCCode) else code[0].shape[1])
    v, squeeze = _expand(jnp.asarray(values))
    e = jnp.asarray(erased, bool)
    if backend == "sparse":
        idx, coeff = _tables(code)
        v, e, d = _peel_adaptive_sparse(idx, coeff, v, e, int(max_iters))
    else:
        H, Hb = _mats(code, v.dtype)
        v, e, d = _peel_adaptive(H, Hb, v, e, int(max_iters))
    if squeeze:
        v = v[:, 0]
    return DecodeResult(v, e, d)


def erased_after(code: LDPCCode, erased: np.ndarray, iters: int) -> np.ndarray:
    """Structure-only decode: which coordinates remain erased after D rounds.

    Used by tests and by the density-evolution comparison; does not touch the
    payload values.
    """
    dummy = jnp.zeros((code.N,), jnp.float32)
    res = peel_decode(code, dummy, jnp.asarray(erased, bool), iters)
    return np.asarray(res.erased)


def _float_dtype(dtype):
    return dtype if jnp.issubdtype(dtype, jnp.floating) else jnp.float32


def _mats(code, dtype) -> tuple[jax.Array, jax.Array]:
    if isinstance(code, LDPCCode):
        H = jnp.asarray(code.H, dtype=_float_dtype(dtype))
        Hb = jnp.asarray(code.H_mask)
    else:
        H, Hb = code
        H = jnp.asarray(H)
        Hb = jnp.asarray(Hb, bool)
    return H, Hb


def _tables(code: LDPCCode) -> tuple[jax.Array, jax.Array]:
    return jnp.asarray(code.check_idx), jnp.asarray(code.check_coeff)
