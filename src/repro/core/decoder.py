"""Iterative (peeling) erasure decoder for real-valued LDPC codes, in JAX.

The classic peeling decoder resolves degree-1 checks one at a time.  On TPU
we use the equivalent *flooding* schedule: in each round, every parity check
with exactly one erased neighbour resolves that neighbour.  A flooding round
is a dense ``H``-structured matvec (MXU-friendly) and the fixed number of
rounds ``D`` is exactly the paper's decoding-iteration knob — the quality of
the recovered gradient is monotone in ``D`` (Remark 3).

The decoder is fully ``jit``-able (fixed ``D`` → ``lax.fori_loop``;
adaptive → ``lax.while_loop`` with early exit) and batched over symbol
payloads: ``values`` may be ``(N,)`` scalars (the paper's inner products) or
``(N, V)`` vectors (coded gradient aggregation, where each symbol is a chunk
of a partial gradient).

Erased coordinates that remain unresolved are left as-is in ``values`` but
flagged in the returned mask; callers zero-fill per the paper's Scheme 2
(both ``ĉ`` and ``b̂`` are zeroed on the unresolved set so the estimate stays
an unbiased scaled gradient — Lemma 1).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ldpc import LDPCCode

__all__ = ["DecodeResult", "peel_round", "peel_decode", "peel_decode_adaptive", "erased_after"]


class DecodeResult(NamedTuple):
    values: jax.Array  # (N,) or (N, V); decoded where possible
    erased: jax.Array  # (N,) bool; True where still unresolved
    rounds_used: jax.Array  # () int32 (== D for fixed-D decode)


def _expand(values: jax.Array) -> tuple[jax.Array, bool]:
    if values.ndim == 1:
        return values[:, None], True
    return values, False


def peel_round(
    H: jax.Array, Hb: jax.Array, values: jax.Array, erased: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """One flooding round. values: (N, V), erased: (N,) bool.

    For every check row with exactly one erased neighbour ``j``:
      ``c_j = -(sum_{j' known} H[i, j'] c_{j'}) / H[i, j]``.
    Rows that resolve the same coordinate write consistent values (they are
    parity checks of the same codeword), so duplicate scatters are benign.
    """
    N = values.shape[0]
    e = erased.astype(H.dtype)  # (N,)
    cnt = Hb.astype(H.dtype) @ e  # (p,) number of erased neighbours per check
    solvable = cnt == 1.0  # (p,)
    known = values * (1.0 - e)[:, None]  # zero out erased entries
    row_sums = H @ known  # (p, V)
    # The (unique) erased neighbour of each row; arbitrary for non-solvable rows.
    pos = jnp.argmax(Hb & erased[None, :], axis=1)  # (p,)
    coeff = jnp.take_along_axis(H, pos[:, None], axis=1)[:, 0]  # (p,)
    new_val = -row_sums / jnp.where(coeff == 0.0, 1.0, coeff)[:, None]
    # Out-of-bounds scatter with mode="drop" discards non-solvable rows.
    safe_pos = jnp.where(solvable, pos, N)
    values = values.at[safe_pos].set(new_val, mode="drop")
    erased = erased.at[safe_pos].set(False, mode="drop")
    return values, erased


@partial(jax.jit, static_argnames=("iters",))
def _peel_fixed(H, Hb, values, erased, iters: int):
    def body(_, carry):
        v, e = carry
        return peel_round(H, Hb, v, e)

    values, erased = jax.lax.fori_loop(0, iters, body, (values, erased))
    return values, erased


def peel_decode(
    code: LDPCCode | tuple[jax.Array, jax.Array],
    values: jax.Array,
    erased: jax.Array,
    iters: int,
) -> DecodeResult:
    """Run exactly ``iters`` flooding rounds (the paper's fixed-D decode)."""
    H, Hb = _mats(code, values.dtype)
    v, squeeze = _expand(jnp.asarray(values))
    v, e = _peel_fixed(H, Hb, v, jnp.asarray(erased, bool), int(iters))
    if squeeze:
        v = v[:, 0]
    return DecodeResult(v, e, jnp.int32(iters))


@partial(jax.jit, static_argnames=("max_iters",))
def _peel_adaptive(H, Hb, values, erased, max_iters: int):
    def cond(carry):
        _, e, d, progressed = carry
        return (d < max_iters) & progressed & e.any()

    def body(carry):
        v, e, d, _ = carry
        v2, e2 = peel_round(H, Hb, v, e)
        return v2, e2, d + 1, (e2 != e).any()

    v, e, d, _ = jax.lax.while_loop(
        cond, body, (values, erased, jnp.int32(0), jnp.bool_(True))
    )
    return v, e, d


def peel_decode_adaptive(
    code: LDPCCode | tuple[jax.Array, jax.Array],
    values: jax.Array,
    erased: jax.Array,
    max_iters: int | None = None,
) -> DecodeResult:
    """Decode until fixpoint (no check resolves) or ``max_iters`` rounds.

    This is the "decoding effort adapts to the number of stragglers" mode:
    with few erasures the loop exits after 1-2 rounds.
    """
    H, Hb = _mats(code, values.dtype)
    if max_iters is None:
        max_iters = int(H.shape[1])
    v, squeeze = _expand(jnp.asarray(values))
    v, e, d = _peel_adaptive(H, Hb, v, jnp.asarray(erased, bool), int(max_iters))
    if squeeze:
        v = v[:, 0]
    return DecodeResult(v, e, d)


def erased_after(code: LDPCCode, erased: np.ndarray, iters: int) -> np.ndarray:
    """Structure-only decode: which coordinates remain erased after D rounds.

    Used by tests and by the density-evolution comparison; does not touch the
    payload values.
    """
    dummy = jnp.zeros((code.N,), jnp.float32)
    res = peel_decode(code, dummy, jnp.asarray(erased, bool), iters)
    return np.asarray(res.erased)


def _mats(code, dtype) -> tuple[jax.Array, jax.Array]:
    if isinstance(code, LDPCCode):
        H = jnp.asarray(code.H, dtype=dtype if jnp.issubdtype(dtype, jnp.floating) else jnp.float32)
        Hb = jnp.asarray(code.H_mask)
    else:
        H, Hb = code
        H = jnp.asarray(H)
        Hb = jnp.asarray(Hb, bool)
    return H, Hb
