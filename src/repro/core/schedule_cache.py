"""Cross-step LRU cache of pattern-compiled peeling schedules.

The peeling elimination order is a pure function of ``(code, erasure
pattern)`` — never of the payload values — and straggler patterns recur
heavily (worker straggling is sticky; the EMA telemetry exists because of
it).  :class:`ScheduleCache` closes that loop: the first decode of a
pattern pays the one-time symbolic solve
(:func:`repro.core.decoder.compile_peel_schedule`, O(rounds · edges) host
work), every later decode of the same pattern replays the cached
:class:`~repro.core.decoder.PeelSchedule` as straight-line gather/FMA
arithmetic (``backend="replay"``) with zero round-loop or convergence
overhead.

Keys are ``(id(code), packed erasure bitmask)``.  The cache holds a strong
reference to every code it has seen, so ``id()`` can never be recycled
onto a different live code object; a stale-by-content entry is impossible
because the mask bytes ARE the pattern and the schedule stores the same
fingerprint (``PeelSchedule.mask_key``), which the decode entry points
re-verify against concrete masks.

Eviction is LRU by access order with a fixed ``capacity``; a recurring
straggler working set therefore stays resident while one-off patterns age
out.  Hits/misses/evictions and the per-solve latency are recorded via
:mod:`repro.obs` when a registry is enabled (``sched_cache.hit`` /
``sched_cache.miss`` / ``sched_cache.evict`` counters, the
``sched_cache.solve_s`` latency histogram, and a ``sched_cache.hit_rate``
gauge), so serving/distributed runs can gate on the realized hit rate.

Thread-safety: a single lock around every operation — the driver loops
are single-threaded hosts, but the serving batcher's admission path may
touch the cache from callback context.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict

import jax
import numpy as np

from repro.core.decoder import (
    PeelSchedule,
    compile_peel_schedule,
    erasure_mask_key,
)
from repro.obs import metrics as _obs_metrics

__all__ = ["ScheduleCache", "DEFAULT_CAPACITY"]

DEFAULT_CAPACITY = 256


class ScheduleCache:
    """LRU ``(code, erasure pattern) -> PeelSchedule`` with obs counters."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        if int(capacity) < 1:
            raise ValueError(f"capacity must be >= 1; got {capacity}")
        self.capacity = int(capacity)
        self._entries: OrderedDict[tuple, PeelSchedule] = OrderedDict()
        self._codes: dict[int, object] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, code, erased) -> PeelSchedule:
        """The schedule for ``(code, erased)`` — cached, or solved on miss.

        ``erased`` must be a CONCRETE (N,) mask; under jit the pattern is a
        tracer and there is nothing to key on — solve at dispatch time
        (where the mask is host-known, e.g. the async pipeline's plan loop)
        and pass the schedule into the decode instead.
        """
        if isinstance(erased, jax.core.Tracer):
            raise ValueError(
                "ScheduleCache.get needs a CONCRETE erasure mask (the cache "
                "key is the packed pattern); under jit, look the schedule "
                "up outside the traced region and pass it via schedule=")
        key = (id(code), erasure_mask_key(erased))
        reg = _obs_metrics.active()
        with self._lock:
            sched = self._entries.get(key)
            if sched is not None:
                self._entries.move_to_end(key)
                self.hits += 1
                if reg is not None:
                    reg.counter("sched_cache.hit").inc()
                    self._record_rate(reg)
                return sched
        t0 = time.perf_counter()
        sched = compile_peel_schedule(code, erased)
        solve_s = time.perf_counter() - t0
        with self._lock:
            self.misses += 1
            if reg is not None:
                reg.counter("sched_cache.miss").inc()
                reg.histogram("sched_cache.solve_s",
                              bins=_obs_metrics.LATENCY_BINS).observe(solve_s)
                self._record_rate(reg)
            self._codes[id(code)] = code
            self._entries[key] = sched
            while len(self._entries) > self.capacity:
                old_key, _ = self._entries.popitem(last=False)
                self.evictions += 1
                if reg is not None:
                    reg.counter("sched_cache.evict").inc()
                if not any(k[0] == old_key[0] for k in self._entries):
                    self._codes.pop(old_key[0], None)
        return sched

    def get_batch(self, code, erased) -> tuple[PeelSchedule, ...]:
        """Per-slot schedules for a concrete (B, N) mask batch — the
        ``schedules=`` operand of the batched replay decodes; each slot
        hits or misses independently."""
        if isinstance(erased, jax.core.Tracer):
            raise ValueError(
                "ScheduleCache.get_batch needs CONCRETE per-slot erasure "
                "masks; under jit, look the schedules up outside the traced "
                "region and pass them via schedules=")
        e = np.asarray(erased, bool)
        if e.ndim != 2:
            raise ValueError(f"erased must be (B, N); got shape {e.shape}")
        return tuple(self.get(code, e[b]) for b in range(e.shape[0]))

    def _record_rate(self, reg) -> None:
        total = self.hits + self.misses
        if total:
            reg.gauge("sched_cache.hit_rate").set(self.hits / total)

    def stats(self) -> dict:
        """Hit/miss/eviction counters, occupancy, and the realized hit
        rate — what the replay benchmark gates on."""
        total = self.hits + self.misses
        return {
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "size": len(self._entries),
            "capacity": self.capacity,
            "hit_rate": self.hits / total if total else 0.0,
        }

    def clear(self) -> None:
        """Drop every entry (counters keep accumulating — they describe
        the cache's lifetime, not its current contents)."""
        with self._lock:
            self._entries.clear()
            self._codes.clear()
