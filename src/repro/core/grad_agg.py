"""Beyond-paper: LDPC-coded gradient aggregation for arbitrary additive losses.

The paper's moment encoding is specific to squared loss (only there does the
gradient factor through a fixed matrix ``M = X^T X``).  The transferable
insight — *add sparse linear redundancy across workers' partial results and
peel-decode erasures at the aggregator* — applies to ANY loss of the form
``L(θ) = Σ_i ℓ_i(θ)``, including every architecture in the model zoo:

* the data is split into ``K`` shards; shard ``i``'s partial gradient
  ``g_i`` (flattened) is the ``i``-th *systematic* symbol;
* ``p`` parity workers each hold the union of ``r-1`` shards (LDGM rows must
  be sparse so a parity worker's data footprint stays small — this is why
  :func:`repro.core.ldpc.make_ldgm` exists) and return the weighted sum
  ``c_j = Σ_i P[j,i] g_i``;
* the master peels for ``D`` rounds; unresolved systematic symbols are
  zero-filled.  Lemma 1's argument carries verbatim: under Bernoulli(q0)
  straggling the aggregate is an unbiased ``(1 - q_D)``-scaled gradient.

On a TPU mesh the "workers" are data-parallel shards and this substitutes
the plain gradient all-reduce; see launch/train.py's ``--coded-agg`` flag.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.engine import CodedComputeEngine
from repro.core.ldpc import LDPCCode, make_ldgm

__all__ = ["CodedAggregator", "flatten_grads", "unflatten_grads"]


def flatten_grads(tree) -> tuple[jax.Array, Callable]:
    """Flatten a gradient pytree to a single vector (and an inverse)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = [l.shape for l in leaves]
    sizes = [l.size for l in leaves]
    flat = jnp.concatenate([l.reshape(-1) for l in leaves]) if leaves else jnp.zeros((0,))

    def unflatten(vec):
        out, off = [], 0
        for sh, sz in zip(shapes, sizes):
            out.append(vec[off : off + sz].reshape(sh))
            off += sz
        return jax.tree_util.tree_unflatten(treedef, out)

    return flat, unflatten


def unflatten_grads(vec, like):
    _, unflat = flatten_grads(like)
    return unflat(vec)


@dataclasses.dataclass(frozen=True)
class CodedAggregator:
    """LDPC(-LDGM)-coded sum of K partial gradients with straggler erasures.

    ``aggregate(partials, mask, iters)``: ``partials`` is (K, dim) — the
    systematic symbols.  Parity symbols are formed *as the parity workers
    would* (sparse combos of the shards each parity worker owns), then the
    straggler mask erases worker symbols and the master peels.  Returns the
    zero-filled sum ``Σ_i ĝ_i`` and the number of unresolved shards.
    """

    code: LDPCCode
    decode_iters: int = 8
    decode_backend: str = "auto"  # dense | sparse | pallas | auto (decoder.py)
    debias_scale: float = 1.0  # optional 1/(1-q_D) correction

    @classmethod
    def build(cls, n_shards: int, *, redundancy: float = 0.5, row_weight: int = 4,
              seed: int = 0, **kw) -> "CodedAggregator":
        p = max(1, int(round(n_shards * redundancy)))
        return cls(code=make_ldgm(n_shards, p, row_weight=row_weight, seed=seed), **kw)

    @property
    def n_workers(self) -> int:
        return self.code.N

    @property
    def n_shards(self) -> int:
        return self.code.K

    @property
    def engine(self) -> CodedComputeEngine:
        return CodedComputeEngine(self.code, decode_iters=self.decode_iters,
                                  backend=self.decode_backend)

    def encode(self, partials: jax.Array) -> jax.Array:
        """(K, dim) systematic partial gradients -> (N, dim) worker symbols."""
        return self.engine.encode(partials)

    def aggregate(self, partials: jax.Array, straggler_mask: jax.Array
                  ) -> tuple[jax.Array, jax.Array]:
        # The full engine pipeline: encode → erase → decode → zero-fill.
        recovered, unresolved = self.engine.recover(
            self.encode(partials), straggler_mask)
        total = recovered.sum(axis=0) * self.debias_scale
        return total, unresolved.sum()
