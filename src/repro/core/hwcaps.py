"""Hardware-capability shim: the FLOPs/bytes model behind ``seeded_mode="auto"``.

ROADMAP item 5 asks for a small capability layer so dispatch decisions made
analytically on CPU-interpret CI carry over to real TPU runs with measured
numbers behind them.  This module is that seam: :func:`detect_caps` reports
the platform and a single scalar — ``mxu_advantage``, the effective FLOPs
multiplier the dense regenerated-tile round enjoys because its inner product
runs on the MXU while the gather round's FMA chain runs on the VPU — and
:func:`pick_seeded_mode` folds it into the dense-vs-gather crossover:

    gather  iff  dense_flops > mxu_advantage * gather_flops

On CPU (interpret-mode CI) both paths run scalar code, so
``mxu_advantage = 1.0`` and gather wins everywhere its modeled FLOPs are
lower (N/r ≫ 1: always, for real codes).  On TPU the placeholder advantage
is 8.0 — a deliberately conservative stand-in until ROADMAP item 5's
profiling replaces it with measured per-(N, r) counters; the dispatch rule
and every caller stay unchanged when that lands.  Until then the
``REPRO_MXU_ADVANTAGE`` environment variable overrides the TPU placeholder
(a positive float, e.g. from a one-off microbenchmark on the actual part),
so deployments can correct the crossover without a code change.

The per-round FLOPs models count the work of ONE flooding round at padded
shapes (``p_pad × n_pad`` dense tiles vs ``p_pad × r`` gathered edges plus
the inverse-permutation scatter merge), mirroring the kernel loop structure
in ``repro.kernels.ldpc_peel.kernel`` — they are the same expressions the
``seeded_gather`` benchmark section records and CI gates on.
"""
from __future__ import annotations

import dataclasses
import os

import jax

__all__ = ["HardwareCaps", "detect_caps", "seeded_dense_round_flops",
           "seeded_gather_round_flops", "pick_seeded_mode",
           "MXU_ADVANTAGE_ENV", "DEFAULT_TPU_MXU_ADVANTAGE"]

# Placeholder MXU advantage on TPU until ROADMAP item 5's profiling lands,
# and the env var that overrides it per deployment (positive float).
DEFAULT_TPU_MXU_ADVANTAGE = 8.0
MXU_ADVANTAGE_ENV = "REPRO_MXU_ADVANTAGE"


def _tpu_mxu_advantage() -> float:
    """The TPU ``mxu_advantage``: the ``REPRO_MXU_ADVANTAGE`` env override
    when set (validated positive float — a bad value fails loudly here
    rather than silently skewing every auto dispatch), else the
    placeholder."""
    raw = os.environ.get(MXU_ADVANTAGE_ENV)
    if raw is None:
        return DEFAULT_TPU_MXU_ADVANTAGE
    try:
        val = float(raw)
    except ValueError:
        raise ValueError(
            f"{MXU_ADVANTAGE_ENV}={raw!r} is not a float; expected a "
            "positive FLOPs multiplier (e.g. 8.0)") from None
    if not val > 0.0 or val != val or val == float("inf"):
        raise ValueError(
            f"{MXU_ADVANTAGE_ENV}={raw!r} must be a finite positive "
            "FLOPs multiplier")
    return val


def _pad_to(x: int, m: int) -> int:
    return x + (-x) % m


@dataclasses.dataclass(frozen=True)
class HardwareCaps:
    """What the dispatch model knows about the accelerator.

    ``mxu_advantage`` — effective dense-matmul FLOPs discount vs scalar VPU
    work: the dense round's FLOPs count is divided by it before comparing
    against the gather round's.  1.0 on CPU/interpret; on TPU the
    ``REPRO_MXU_ADVANTAGE`` env override when set, else the 8.0 placeholder
    until real profiling (ROADMAP item 5) supplies measured values.
    """

    platform: str
    mxu_advantage: float


def detect_caps(platform: str | None = None) -> HardwareCaps:
    """Capabilities of the default JAX backend (or an explicit platform).

    The env override is read per call (not cached at import), so tests and
    long-lived processes that adjust ``REPRO_MXU_ADVANTAGE`` see the new
    value on the next dispatch decision."""
    if platform is None:
        platform = jax.default_backend()
    return HardwareCaps(
        platform=platform,
        mxu_advantage=_tpu_mxu_advantage() if platform == "tpu" else 1.0)


def seeded_dense_round_flops(spec, V: int, *, bp: int = 128) -> int:
    """Modeled FLOPs of ONE dense-regenerated-tile round.

    Per ``bp × n_pad`` tile: regenerate the tile (~5 ops/entry), the
    ``H_tile @ [vals, e, pos]`` contractions (2 FLOPs/entry each over V
    payload lanes + 2 structure lanes), and the O(p) row epilogue folded
    into the per-entry count: ≈ ``p_pad · n_pad · (4V + 7)``.
    """
    p_pad = _pad_to(spec.rows, min(bp, _pad_to(spec.rows, 8)))
    n_pad = _pad_to(spec.cols, 128)
    return p_pad * n_pad * (4 * V + 7)


def seeded_gather_round_flops(spec, V: int, *, bp: int = 128) -> int:
    """Modeled FLOPs of ONE gather/segment-sum round.

    Check pass: r gathered edges per check row, each a weight draw + FMA
    over V lanes + cnt/pos/coeff updates ≈ ``p_pad · r · (2V + 6)``.
    Merge pass: the inverse-permutation scatter visits each variable once
    per layer per tile ≈ ``n_tiles · n_pad · l · (2V + 8)``.
    """
    bp_eff = min(bp, _pad_to(spec.rows, 8))
    p_pad = _pad_to(spec.rows, bp_eff)
    n_pad = _pad_to(spec.cols, 128)
    n_tiles = p_pad // bp_eff
    r = spec.row_weight
    l = spec.layers
    return (p_pad * r * (2 * V + 6)
            + n_tiles * n_pad * l * (2 * V + 8))


def pick_seeded_mode(spec, V: int = 1, *, bp: int = 128,
                     caps: HardwareCaps | None = None) -> str:
    """Resolve ``seeded_mode="auto"``: "gather" iff the dense round's
    modeled FLOPs exceed ``mxu_advantage ×`` the gather round's."""
    if caps is None:
        caps = detect_caps()
    dense = seeded_dense_round_flops(spec, V, bp=bp)
    gather = seeded_gather_round_flops(spec, V, bp=bp)
    return "gather" if dense > caps.mxu_advantage * gather else "dense_tile"
