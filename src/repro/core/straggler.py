"""Straggler models.

The paper analyzes Assumption 1 (each worker independently straggles with
probability ``q0``) and experiments with a fixed straggler count ``s`` out of
``w = 40`` workers.  On a synchronous TPU mesh there are no real stragglers,
so the mask is *injected*: it is exactly the erasure-channel abstraction the
analysis is built on.  Masks are produced with JAX PRNG so coded steps stay
jit-able, and a shifted-exponential delay model supports wall-clock
simulation for the benchmark harness (time of a step = the order statistic
of worker delays at the wait-for threshold).
"""
from __future__ import annotations

import dataclasses
from typing import Protocol

import jax
import jax.numpy as jnp

__all__ = [
    "StragglerModel",
    "BernoulliStragglers",
    "FixedCountStragglers",
    "AdversarialStragglers",
    "DelayModel",
]


class StragglerModel(Protocol):
    def sample(self, key: jax.Array, w: int) -> jax.Array:
        """Return a (w,) bool mask, True = straggler (erased)."""
        ...


@dataclasses.dataclass(frozen=True)
class BernoulliStragglers:
    """Assumption 1: i.i.d. Bernoulli(q0) straggling per worker per step."""

    q0: float

    def sample(self, key: jax.Array, w: int) -> jax.Array:
        return jax.random.bernoulli(key, self.q0, (w,))


@dataclasses.dataclass(frozen=True)
class FixedCountStragglers:
    """Exactly ``s`` uniformly-random stragglers per step (the paper's
    experimental setting: wait for the fastest ``w - s`` workers).

    The mask is built from a random permutation's first ``s`` indices, so
    the count is exactly ``s`` by construction.  (The previous
    ``scores >= top_k(scores, s)[-1]`` comparison over-erased whenever the
    threshold score was tied — f32 uniforms collide with probability
    ~``w²/2²⁵`` per step, which is a real event over long runs.)
    """

    s: int

    def sample(self, key: jax.Array, w: int) -> jax.Array:
        if self.s <= 0:
            return jnp.zeros((w,), bool)
        idx = jax.random.permutation(key, w)[: self.s]
        return jnp.zeros((w,), bool).at[idx].set(True)


@dataclasses.dataclass(frozen=True)
class AdversarialStragglers:
    """The same fixed set of workers straggles every step (worst case for
    schemes without redundancy diversity)."""

    indices: tuple[int, ...]

    def sample(self, key: jax.Array, w: int) -> jax.Array:
        del key
        mask = jnp.zeros((w,), bool)
        if self.indices:
            mask = mask.at[jnp.asarray(self.indices)].set(True)
        return mask


@dataclasses.dataclass(frozen=True)
class DelayModel:
    """Shifted-exponential worker latency: d_j = tau + Exp(rate=mu).

    ``sample_delays`` gives per-worker latencies; ``step_time(delays, wait)``
    is the wall-clock cost of waiting for the fastest ``wait`` workers, and
    the implied straggler mask is "not among the fastest ``wait``".
    This reproduces the paper's wall-time comparisons without a real cluster.
    """

    tau: float = 1.0
    mu: float = 1.0

    def sample_delays(self, key: jax.Array, w: int) -> jax.Array:
        return self.tau + jax.random.exponential(key, (w,)) / self.mu

    @staticmethod
    def mask_and_time(delays: jax.Array, wait_for: int) -> tuple[jax.Array, jax.Array]:
        w = delays.shape[0]
        order = jnp.argsort(delays)
        cutoff = delays[order[wait_for - 1]]
        mask = delays > cutoff  # stragglers: slower than the wait-for cutoff
        return mask, cutoff
