"""Straggler models.

The paper analyzes Assumption 1 (each worker independently straggles with
probability ``q0``) and experiments with a fixed straggler count ``s`` out of
``w = 40`` workers.  On a synchronous TPU mesh there are no real stragglers,
so the mask is *injected*: it is exactly the erasure-channel abstraction the
analysis is built on.  Masks are produced with JAX PRNG so coded steps stay
jit-able, and a shifted-exponential delay model supports wall-clock
simulation for the benchmark harness (time of a step = the order statistic
of worker delays at the wait-for threshold).
"""
from __future__ import annotations

import dataclasses
from typing import Protocol

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "StragglerModel",
    "BernoulliStragglers",
    "FixedCountStragglers",
    "AdversarialStragglers",
    "DelayModel",
    "ScheduledDelays",
]


class StragglerModel(Protocol):
    def sample(self, key: jax.Array, w: int) -> jax.Array:
        """Return a (w,) bool mask, True = straggler (erased)."""
        ...


@dataclasses.dataclass(frozen=True)
class BernoulliStragglers:
    """Assumption 1: i.i.d. Bernoulli(q0) straggling per worker per step."""

    q0: float

    def sample(self, key: jax.Array, w: int) -> jax.Array:
        return jax.random.bernoulli(key, self.q0, (w,))


@dataclasses.dataclass(frozen=True)
class FixedCountStragglers:
    """Exactly ``s`` uniformly-random stragglers per step (the paper's
    experimental setting: wait for the fastest ``w - s`` workers).

    The mask is built from a random permutation's first ``s`` indices, so
    the count is exactly ``s`` by construction.  (The previous
    ``scores >= top_k(scores, s)[-1]`` comparison over-erased whenever the
    threshold score was tied — f32 uniforms collide with probability
    ~``w²/2²⁵`` per step, which is a real event over long runs.)
    """

    s: int

    def sample(self, key: jax.Array, w: int) -> jax.Array:
        if self.s <= 0:
            return jnp.zeros((w,), bool)
        idx = jax.random.permutation(key, w)[: self.s]
        return jnp.zeros((w,), bool).at[idx].set(True)


@dataclasses.dataclass(frozen=True)
class AdversarialStragglers:
    """The same fixed set of workers straggles every step (worst case for
    schemes without redundancy diversity)."""

    indices: tuple[int, ...]

    def sample(self, key: jax.Array, w: int) -> jax.Array:
        del key
        mask = jnp.zeros((w,), bool)
        if self.indices:
            mask = mask.at[jnp.asarray(self.indices)].set(True)
        return mask


@dataclasses.dataclass(frozen=True)
class DelayModel:
    """Shifted-exponential worker latency: d_j = tau + Exp(rate=mu).

    ``sample_delays`` gives per-worker latencies; ``step_time(delays, wait)``
    is the wall-clock cost of waiting for the fastest ``wait`` workers, and
    the implied straggler mask is "not among the fastest ``wait``".
    This reproduces the paper's wall-time comparisons without a real cluster.
    """

    tau: float = 1.0
    mu: float = 1.0

    def sample_delays(self, key: jax.Array, w: int) -> jax.Array:
        return self.tau + jax.random.exponential(key, (w,)) / self.mu

    @staticmethod
    def mask_and_time(delays: jax.Array, wait_for: int) -> tuple[jax.Array, jax.Array]:
        w = delays.shape[0]
        order = jnp.argsort(delays)
        cutoff = delays[order[wait_for - 1]]
        mask = delays > cutoff  # stragglers: slower than the wait-for cutoff
        return mask, cutoff

    @staticmethod
    def arrival_lags(delays, cutoff) -> np.ndarray:
        """Per-worker arrival lag in STEP-LENGTH units (host-side numpy).

        A worker slower than the wait-for ``cutoff`` misses this step; if
        steps keep taking about ``cutoff`` wall-clock, its partial product
        lands ``ceil((d - cutoff) / cutoff)`` steps later.  0 = arrived on
        time.  The pipelined runtime folds lags within ``max_staleness``
        into later updates and treats larger lags as today's drop.
        """
        d = np.asarray(delays, float)
        cutoff = float(cutoff)
        late = np.maximum(d - cutoff, 0.0)
        with np.errstate(invalid="ignore"):
            lags = np.ceil(late / max(cutoff, 1e-30))
        return lags.astype(int)


@dataclasses.dataclass(frozen=True)
class ScheduledDelays:
    """Deterministic per-step worker latencies from a fixed table.

    ``schedule`` is ``(T, w)``: row ``t`` is the per-worker delay vector of
    step ``t`` (cycled if the run is longer).  Shares :class:`DelayModel`'s
    driver-facing surface (``sample_delays`` keyed by step, ``mask_and_time``
    / ``arrival_lags`` via the DelayModel staticmethods), so
    ``DistributedCodedGD.run`` and the pipelined runtime accept it wherever
    a ``delay_model`` goes.  The benchmark's pipeline section uses it to
    put the synchronous and pipelined runtimes under the SAME injected
    arrival pattern — the speedup ratio then cannot hide behind sampling
    noise.
    """

    schedule: tuple  # (T, w) nested tuple of floats; frozen-dataclass safe
    _step: dict = dataclasses.field(default_factory=dict, hash=False,
                                    compare=False)

    @staticmethod
    def build(schedule) -> "ScheduledDelays":
        arr = np.asarray(schedule, float)
        if arr.ndim != 2:
            raise ValueError(f"schedule must be (T, w); got {arr.shape}")
        return ScheduledDelays(tuple(map(tuple, arr.tolist())))

    mask_and_time = staticmethod(DelayModel.mask_and_time)
    arrival_lags = staticmethod(DelayModel.arrival_lags)

    def sample_delays(self, key: jax.Array, w: int) -> jax.Array:
        """Row ``t`` of the table, keyed by call order (one call per step,
        mirroring how the drivers consume a DelayModel)."""
        t = self._step.get("t", 0)
        self._step["t"] = t + 1
        row = self.schedule[t % len(self.schedule)]
        if len(row) != w:
            raise ValueError(f"schedule rows cover {len(row)} workers; "
                             f"driver asked for {w}")
        return jnp.asarray(row, jnp.float32)

    def reset(self) -> None:
        self._step.clear()
