"""Density evolution for (l, r)-regular LDPC erasure decoding (Proposition 2).

``q_d = q0 * (1 - (1 - q_{d-1})^(r-1))^(l-1)`` is the probability that a
codeword coordinate remains erased after ``d`` peeling iterations, when each
coordinate is independently erased with probability ``q0`` (the paper's
Assumption 1 straggler model).  ``q_d`` is monotone non-increasing iff
``q0 < q*(l, r)`` (Remark 3); ``q*`` is the ensemble threshold.
"""
from __future__ import annotations

import numpy as np

__all__ = ["qd_sequence", "q_final", "threshold"]


def qd_sequence(q0: float, l: int, r: int, D: int) -> np.ndarray:
    """[q_0, q_1, ..., q_D] under the density-evolution recursion."""
    qs = [float(q0)]
    for _ in range(D):
        q = qs[-1]
        qs.append(q0 * (1.0 - (1.0 - q) ** (r - 1)) ** (l - 1))
    return np.array(qs)


def q_final(q0: float, l: int, r: int, D: int) -> float:
    """q_D — the erasure probability entering Lemma 1 / Theorem 1."""
    return float(qd_sequence(q0, l, r, D)[-1])


def threshold(l: int, r: int, *, iters: int = 2000, tol: float = 1e-9) -> float:
    """Erasure threshold q*(l, r): sup{q0 : q_d -> 0}.

    Found by bisection on whether the recursion converges to (near) zero.
    E.g. q*(3, 6) ~= 0.4294 (Richardson & Urbanke).
    """

    def converges(q0: float) -> bool:
        q = q0
        for _ in range(iters):
            q = q0 * (1.0 - (1.0 - q) ** (r - 1)) ** (l - 1)
            if q < 1e-12:
                return True
        return q < 1e-10

    lo, hi = 0.0, 1.0
    while hi - lo > tol:
        mid = 0.5 * (lo + hi)
        if converges(mid):
            lo = mid
        else:
            hi = mid
    return lo
