"""The paper's primary contribution: LDPC moment-encoded robust gradient descent."""
from repro.core.ldpc import LDPCCode, make_regular_ldpc, make_ldgm
from repro.core.decoder import peel_decode, peel_decode_adaptive, DecodeResult
from repro.core.density_evolution import qd_sequence, q_final, threshold
from repro.core.encoding import Moments, second_moment, encode_moment, encode_moment_blocks
from repro.core.coded_step import Scheme1, Scheme2, Scheme2Blocked, run_pgd, RunResult
from repro.core.straggler import (
    BernoulliStragglers,
    FixedCountStragglers,
    AdversarialStragglers,
    DelayModel,
)
from repro.core.grad_agg import CodedAggregator, flatten_grads

__all__ = [
    "LDPCCode", "make_regular_ldpc", "make_ldgm",
    "peel_decode", "peel_decode_adaptive", "DecodeResult",
    "qd_sequence", "q_final", "threshold",
    "Moments", "second_moment", "encode_moment", "encode_moment_blocks",
    "Scheme1", "Scheme2", "Scheme2Blocked", "run_pgd", "RunResult",
    "BernoulliStragglers", "FixedCountStragglers", "AdversarialStragglers", "DelayModel",
    "CodedAggregator", "flatten_grads",
]
