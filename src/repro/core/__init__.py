"""The paper's primary contribution: LDPC moment-encoded robust gradient descent."""
from repro.core.ldpc import LDPCCode, make_regular_ldpc, make_ldgm
from repro.core.decoder import (
    peel_decode,
    peel_decode_adaptive,
    peel_decode_batch,
    peel_decode_batch_adaptive,
    compile_peel_schedule,
    erasure_mask_key,
    DecodeResult,
    PeelSchedule,
)
from repro.core.engine import CodedComputeEngine, blocked_epilogue
from repro.core.schedule_cache import ScheduleCache
from repro.core.density_evolution import qd_sequence, q_final, threshold
from repro.core.encoding import Moments, second_moment, encode_moment, encode_moment_blocks
from repro.core.coded_step import Scheme1, Scheme2, Scheme2Blocked, run_pgd, RunResult
from repro.core.schemes import Scheme, scheme_registry
from repro.core.straggler import (
    BernoulliStragglers,
    FixedCountStragglers,
    AdversarialStragglers,
    DelayModel,
    ScheduledDelays,
)
from repro.core.grad_agg import CodedAggregator, flatten_grads
from repro.core.padding import pad_axis_to, pad_blocks

__all__ = [
    "LDPCCode", "make_regular_ldpc", "make_ldgm",
    "peel_decode", "peel_decode_adaptive", "peel_decode_batch",
    "peel_decode_batch_adaptive", "DecodeResult",
    "compile_peel_schedule", "erasure_mask_key", "PeelSchedule",
    "CodedComputeEngine", "blocked_epilogue", "ScheduleCache",
    "qd_sequence", "q_final", "threshold",
    "Moments", "second_moment", "encode_moment", "encode_moment_blocks",
    "Scheme1", "Scheme2", "Scheme2Blocked", "run_pgd", "RunResult",
    "Scheme", "scheme_registry",
    "BernoulliStragglers", "FixedCountStragglers", "AdversarialStragglers", "DelayModel", "ScheduledDelays",
    "CodedAggregator", "flatten_grads",
    "pad_axis_to", "pad_blocks",
]
