"""The paper's coded PGD steps (Schemes 1 and 2), as jit-able JAX functions.

Scheme 2 (the main contribution) per step ``t``:

  1. worker products:   z = C θ_{t-1}            (each worker: one scalar/row)
  2. erasures:          z_S  — stragglers' coordinates masked
  3. peeling decode:    D rounds; unresolved set U_t
  4. zero-fill:         ĉ (and b̂) zeroed on U_t
  5. update:            θ_t = P_Θ(θ_{t-1} - η (ĉ_{1:k} - b̂))

Steps 2–4 are exactly the :class:`repro.core.engine.CodedComputeEngine`
pipeline (erase → decode → epilogue); the schemes here are thin clients
that own the encoded operator ``C`` / moment vector ``b`` and the update
rule, and delegate everything code-related to the engine.  The engine's
batch axis also gives Scheme 2 a batched query path
(:meth:`Scheme2.gradient_batch`): B concurrent (θ, straggler-mask) queries,
one decode launch — the serving primitive behind
:mod:`repro.serving.coded_queries`.

Under Assumption 1 this is PSGD with an unbiased (1-q_D)-scaled gradient
(Lemma 1) and converges at RB/((1-q_D)√T) (Theorem 1).  An optional
``debias`` flag divides the estimate by (1-q_D) — a beyond-paper knob that
makes the estimate exactly unbiased (the paper folds the scale into the
effective learning rate instead).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import density_evolution
from repro.core.encoding import (Moments, encode_moment,
                                 encode_moment_blocks, encode_seeded,
                                 gather_encode, generator_gather_tables)
from repro.core.engine import CodedComputeEngine, blocked_epilogue
from repro.core.ldpc import LDPCCode
from repro.optim import projections

__all__ = ["Scheme2", "Scheme2Blocked", "Scheme1", "run_pgd", "RunResult"]


class RunResult(NamedTuple):
    theta: jax.Array          # final iterate
    theta_bar: jax.Array      # running average (Theorem 1 is stated for it)
    errors: jax.Array         # (T,) ||theta_t - theta*|| if theta_star given, else loss
    unresolved: jax.Array     # (T,) |U_t| — decode quality per step


@dataclasses.dataclass(frozen=True)
class Scheme2:
    """LDPC moment-encoded approximate-gradient PGD (paper Scheme 2)."""

    code: LDPCCode
    C: jax.Array  # (N, k) encoded moment  = G @ M
    b: jax.Array  # (k,)  = X^T y
    lr: float
    decode_iters: int = 10
    adaptive: bool = False
    decode_backend: str = "auto"  # dense | sparse | pallas | auto (decoder.py)
    projection: Callable[[jax.Array], jax.Array] = projections.identity
    debias: bool = False
    q0_for_debias: float = 0.1
    # Seeded on-the-fly encode: ``C`` holds the RAW (k, k) moment matrix M
    # and every step computes the codeword as a generator gather over
    # ``y = M θ`` — the (N, k) encoded matrix is never materialized, and the
    # per-row gather+sum is the SAME one the sharded workers run
    # (bit-identical products to the distributed runtime).
    seeded_encode: bool = False
    # With ``encode_fused=True`` the generator gather runs inside the fused
    # Pallas encode kernel (:func:`repro.core.encoding.encode_seeded`):
    # gather indices regenerate in-register, so not even the (N, r+1)
    # tables exist.  Bit-identical to the table gather under jit (the
    # kernel and the sequential ``gather_encode`` lower to the same FMA
    # chain) — and to the ``worker_encode="seeded-fused"`` distributed
    # runtime, which runs the same kernel per shard.
    encode_fused: bool = False
    # ``decode_backend="replay"`` only: the cross-step LRU of compiled
    # peeling schedules (:class:`repro.core.schedule_cache.ScheduleCache`),
    # threaded into every engine the scheme constructs so recurring
    # straggler patterns pay the symbolic solve once.  ``None`` with the
    # replay backend means concrete-mask decodes solve per call (still
    # bit-correct, just uncached); other backends ignore it.
    schedule_cache: object | None = None

    @classmethod
    def build(cls, code: LDPCCode, moments: Moments, *, lr: float, **kw) -> "Scheme2":
        return cls(code=code, C=encode_moment(code, moments.M), b=moments.b, lr=lr, **kw)

    @classmethod
    def build_seeded(cls, code: LDPCCode, moments: Moments, *, lr: float,
                     **kw) -> "Scheme2":
        """Scheme 2 over a seeded LDGM code with on-the-fly encode: stores
        ``M`` itself ((k, k) — the preprocessing output) instead of the
        ``(N, k)`` encoded ``C``, and regenerates each worker's generator
        row from the seed at every step (``z = gather(M θ)``); pass
        ``encode_fused=True`` to run that gather inside the fused Pallas
        encode kernel (no index tables at all)."""
        return cls(code=code, C=jnp.asarray(moments.M), b=moments.b, lr=lr,
                   seeded_encode=True, **kw)

    def _encode(self, y: jax.Array) -> jax.Array:
        """Seeded codeword of ``y`` ((K,) or (K, V)): fused kernel or
        table gather — bit-identical under jit."""
        if self.encode_fused:
            return encode_seeded(self.code, y)
        idx, coeff = generator_gather_tables(self.code)
        return gather_encode(idx, coeff, y)

    @property
    def w(self) -> int:
        return self.code.N

    @property
    def engine(self) -> CodedComputeEngine:
        return CodedComputeEngine(self.code, decode_iters=self.decode_iters,
                                  backend=self.decode_backend,
                                  adaptive=self.adaptive,
                                  schedule_cache=self.schedule_cache)

    def worker_mask_to_erasure(self, mask: jax.Array) -> jax.Array:
        return mask  # N == w: row j <-> worker j

    def _debias(self, g: jax.Array) -> jax.Array:
        if not self.debias:
            return g
        qD = density_evolution.q_final(
            self.q0_for_debias, self.code.l, self.code.r, self.decode_iters
        )
        return g / max(1.0 - qD, 1e-6)

    def finish_gradient(self, c_hat: jax.Array, unresolved: jax.Array):
        """Scheme-2 gradient epilogue from recovered systematic values:
        zero ``b̂`` on the unresolved set, subtract, (optionally) debias.

        Shapes: ``c_hat (K,)`` / ``unresolved (K,)`` or batched ``(B, K)``.
        Returns ``(gradient, unresolved_count)`` with the count reduced over
        the coordinate axis.  This is THE epilogue — :meth:`gradient`,
        :meth:`gradient_batch`, and the serving layer's continuous launches
        (:mod:`repro.serving.coded_queries`) all share it.
        """
        b = self.b if c_hat.ndim == 1 else self.b[None, :]
        b_hat = jnp.where(unresolved, 0.0, b)
        return self._debias(c_hat - b_hat), unresolved.sum(axis=-1)

    def gradient(self, theta: jax.Array, straggler_mask: jax.Array):
        """Return (approx gradient, |U_t|)."""
        if self.seeded_encode:
            z = self._encode(self.C @ theta)  # gather(M θ)
        else:
            z = self.C @ theta  # (N,) worker inner products (codeword of C)
        erased = self.worker_mask_to_erasure(straggler_mask)
        c_hat, unresolved = self.engine.recover(z, erased)
        return self.finish_gradient(c_hat, unresolved)

    def gradient_batch(self, theta_B: jax.Array, straggler_mask_B: jax.Array):
        """B concurrent queries (θ_b, mask_b) → (B, k) gradients, ONE decode.

        Each query carries its own straggler realization; the worker-product
        matvecs fuse into one (B, k) @ (k, N) matmul and the B peeling
        decodes run as a single batched launch
        (:meth:`CodedComputeEngine.decode_batch`).  Per-query results match
        :meth:`gradient` run separately — including for ``adaptive=True``
        schemes, where each query's decode now early-exits at ITS OWN
        fixpoint (per-slot adaptive batch decode) instead of running the
        whole batch for the worst-case ``decode_iters`` budget.
        """
        if self.seeded_encode:
            Z = self._encode((theta_B @ self.C.T).T).T  # (B, N)
        else:
            Z = theta_B @ self.C.T  # (B, N)
        erased_B = jax.vmap(self.worker_mask_to_erasure)(straggler_mask_B)
        c_hat, unresolved = self.engine.recover_batch(Z, erased_B)
        return self.finish_gradient(c_hat, unresolved)

    def step(self, theta: jax.Array, straggler_mask: jax.Array) -> tuple[jax.Array, jax.Array]:
        g, n_unresolved = self.gradient(theta, straggler_mask)
        return self.projection(theta - self.lr * g), n_unresolved


@dataclasses.dataclass(frozen=True)
class Scheme1:
    """Exact-gradient coded PGD (paper Scheme 1): any linear code, exact
    recovery of M θ from the non-straggling rows via least squares.

    Exact as long as #stragglers < d_min (Proposition 1); with more
    stragglers the per-block least-squares solve is underdetermined and the
    recovered gradient degrades (the lstsq minimum-norm solution is used).
    """

    code: LDPCCode
    C_blocks: jax.Array  # (k/K, N, k)
    b: jax.Array
    lr: float
    projection: Callable[[jax.Array], jax.Array] = projections.identity

    @classmethod
    def build(cls, code: LDPCCode, moments: Moments, *, lr: float, **kw) -> "Scheme1":
        return cls(code=code, C_blocks=encode_moment_blocks(code, moments.M),
                   b=moments.b, lr=lr, **kw)

    @property
    def w(self) -> int:
        return self.code.N

    def gradient(self, theta: jax.Array, straggler_mask: jax.Array):
        G = jnp.asarray(self.code.G, theta.dtype)  # (N, K)
        # Worker j computes one inner product per block: Z[i, j] = <C[i, j], theta>.
        Z = jnp.einsum("bnk,k->bn", self.C_blocks, theta)  # (k/K, N)
        avail = (~straggler_mask).astype(theta.dtype)
        # Weighted least squares that zeroes out straggler rows:
        Gw = G * avail[:, None]
        Zw = Z * avail[None, :]

        def solve(zb):
            sol, *_ = jnp.linalg.lstsq(Gw, zb)
            return sol  # (K,) = M_{P_i} theta

        Mtheta = jax.vmap(solve)(Zw).reshape(-1)  # (k,)
        return Mtheta - self.b, jnp.int32(0)

    def step(self, theta, straggler_mask):
        g, aux = self.gradient(theta, straggler_mask)
        return self.projection(theta - self.lr * g), aux


@dataclasses.dataclass(frozen=True)
class Scheme2Blocked:
    """Scheme 2 generalized to k > K (paper footnote 2): the k rows of M are
    partitioned into k/K blocks, each encoded with the SAME (N=w, K) code;
    worker j holds row j of every block (α = k/K rows) and returns α scalars.

    Because a straggler erases the same coordinate of EVERY block's codeword,
    all k/K codewords share one erasure pattern — the decode is one
    payload-batched peeling pass with payload width k/K (the engine's V
    axis, orthogonal to its B axis of independent patterns).  This is the
    configuration of the paper's experiments: a (40, 20) code with
    k ∈ {200, ..., 2000}.
    """

    code: LDPCCode
    C_blocks: jax.Array  # (k/K, N, k)
    b: jax.Array         # (k,)
    lr: float
    decode_iters: int = 10
    decode_backend: str = "auto"  # dense | sparse | pallas | auto (decoder.py)
    projection: Callable[[jax.Array], jax.Array] = projections.identity

    @classmethod
    def build(cls, code: LDPCCode, moments: Moments, *, lr: float, **kw):
        return cls(code=code, C_blocks=encode_moment_blocks(code, moments.M),
                   b=moments.b, lr=lr, **kw)

    @property
    def w(self) -> int:
        return self.code.N

    @property
    def engine(self) -> CodedComputeEngine:
        return CodedComputeEngine(self.code, decode_iters=self.decode_iters,
                                  backend=self.decode_backend)

    def gradient(self, theta: jax.Array, straggler_mask: jax.Array):
        eng = self.engine
        nb = self.C_blocks.shape[0]
        Z = jnp.einsum("bnk,k->nb", self.C_blocks, theta)  # (N, k/K)
        dec = eng.decode(eng.erase(Z, straggler_mask), straggler_mask)
        g, unresolved_flat = blocked_epilogue(dec.values, dec.erased, self.b,
                                              K=self.code.K, nb=nb)
        return g, unresolved_flat.sum()

    def step(self, theta, straggler_mask):
        g, aux = self.gradient(theta, straggler_mask)
        return self.projection(theta - self.lr * g), aux


def run_pgd(
    scheme,
    theta0: jax.Array,
    straggler_model,
    steps: int,
    *,
    key: jax.Array | None = None,
    theta_star: jax.Array | None = None,
    loss_fn: Callable[[jax.Array], jax.Array] | None = None,
) -> RunResult:
    """Generic driver over any :class:`repro.core.schemes.Scheme`: sample a
    straggler mask, take a coded step, track error.

    Jit-compiled as a single ``lax.scan`` over steps — the whole optimization
    trajectory runs on-device.
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    w = scheme.w

    def metric(theta):
        if theta_star is not None:
            return jnp.linalg.norm(theta - theta_star)
        if loss_fn is not None:
            return loss_fn(theta)
        return jnp.linalg.norm(theta)

    @jax.jit
    def scan_all(theta0, key):
        def body(carry, key_t):
            theta, tbar, t = carry
            mask = straggler_model.sample(key_t, w)
            theta2, unresolved = scheme.step(theta, mask)
            tbar2 = (tbar * t + theta2) / (t + 1.0)
            return (theta2, tbar2, t + 1.0), (metric(theta2), unresolved)

        keys = jax.random.split(key, steps)
        (theta, tbar, _), (errs, unres) = jax.lax.scan(
            body, (theta0, jnp.zeros_like(theta0), 0.0), keys
        )
        return theta, tbar, errs, unres

    theta, tbar, errs, unres = scan_all(theta0, key)
    return RunResult(theta, tbar, errs, unres)
