"""The paper's coded PGD steps (Schemes 1 and 2), as jit-able JAX functions.

Scheme 2 (the main contribution) per step ``t``:

  1. worker products:   z = C θ_{t-1}            (each worker: one scalar/row)
  2. erasures:          z_S  — stragglers' coordinates masked
  3. peeling decode:    D rounds; unresolved set U_t
  4. zero-fill:         ĉ (and b̂) zeroed on U_t
  5. update:            θ_t = P_Θ(θ_{t-1} - η (ĉ_{1:k} - b̂))

Under Assumption 1 this is PSGD with an unbiased (1-q_D)-scaled gradient
(Lemma 1) and converges at RB/((1-q_D)√T) (Theorem 1).  An optional
``debias`` flag divides the estimate by (1-q_D) — a beyond-paper knob that
makes the estimate exactly unbiased (the paper folds the scale into the
effective learning rate instead).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import density_evolution
from repro.core.decoder import peel_decode, peel_decode_adaptive
from repro.core.encoding import Moments, encode_moment, encode_moment_blocks
from repro.core.ldpc import LDPCCode
from repro.optim import projections

__all__ = ["Scheme2", "Scheme2Blocked", "Scheme1", "run_pgd", "RunResult"]


class RunResult(NamedTuple):
    theta: jax.Array          # final iterate
    theta_bar: jax.Array      # running average (Theorem 1 is stated for it)
    errors: jax.Array         # (T,) ||theta_t - theta*|| if theta_star given, else loss
    unresolved: jax.Array     # (T,) |U_t| — decode quality per step


@dataclasses.dataclass(frozen=True)
class Scheme2:
    """LDPC moment-encoded approximate-gradient PGD (paper Scheme 2)."""

    code: LDPCCode
    C: jax.Array  # (N, k) encoded moment  = G @ M
    b: jax.Array  # (k,)  = X^T y
    lr: float
    decode_iters: int = 10
    adaptive: bool = False
    decode_backend: str = "auto"  # dense | sparse | pallas | auto (decoder.py)
    projection: Callable[[jax.Array], jax.Array] = projections.identity
    debias: bool = False
    q0_for_debias: float = 0.1

    @classmethod
    def build(cls, code: LDPCCode, moments: Moments, *, lr: float, **kw) -> "Scheme2":
        return cls(code=code, C=encode_moment(code, moments.M), b=moments.b, lr=lr, **kw)

    @property
    def w(self) -> int:
        return self.code.N

    def worker_mask_to_erasure(self, mask: jax.Array) -> jax.Array:
        return mask  # N == w: row j <-> worker j

    def gradient(self, theta: jax.Array, straggler_mask: jax.Array):
        """Return (approx gradient, |U_t|)."""
        k = self.code.K
        z = self.C @ theta  # (N,) worker inner products (codeword of C)
        erased = self.worker_mask_to_erasure(straggler_mask)
        z = jnp.where(erased, 0.0, z)
        dec = (peel_decode_adaptive if self.adaptive else peel_decode)(
            self.code, z, erased, self.decode_iters, backend=self.decode_backend
        )
        unresolved = dec.erased[:k]
        c_hat = jnp.where(unresolved, 0.0, dec.values[:k])
        b_hat = jnp.where(unresolved, 0.0, self.b)
        g = c_hat - b_hat
        if self.debias:
            qD = density_evolution.q_final(
                self.q0_for_debias, self.code.l, self.code.r, self.decode_iters
            )
            g = g / max(1.0 - qD, 1e-6)
        return g, unresolved.sum()

    def step(self, theta: jax.Array, straggler_mask: jax.Array) -> tuple[jax.Array, jax.Array]:
        g, n_unresolved = self.gradient(theta, straggler_mask)
        return self.projection(theta - self.lr * g), n_unresolved


@dataclasses.dataclass(frozen=True)
class Scheme1:
    """Exact-gradient coded PGD (paper Scheme 1): any linear code, exact
    recovery of M θ from the non-straggling rows via least squares.

    Exact as long as #stragglers < d_min (Proposition 1); with more
    stragglers the per-block least-squares solve is underdetermined and the
    recovered gradient degrades (the lstsq minimum-norm solution is used).
    """

    code: LDPCCode
    C_blocks: jax.Array  # (k/K, N, k)
    b: jax.Array
    lr: float
    projection: Callable[[jax.Array], jax.Array] = projections.identity

    @classmethod
    def build(cls, code: LDPCCode, moments: Moments, *, lr: float, **kw) -> "Scheme1":
        return cls(code=code, C_blocks=encode_moment_blocks(code, moments.M),
                   b=moments.b, lr=lr, **kw)

    @property
    def w(self) -> int:
        return self.code.N

    def gradient(self, theta: jax.Array, straggler_mask: jax.Array):
        G = jnp.asarray(self.code.G, theta.dtype)  # (N, K)
        # Worker j computes one inner product per block: Z[i, j] = <C[i, j], theta>.
        Z = jnp.einsum("bnk,k->bn", self.C_blocks, theta)  # (k/K, N)
        avail = (~straggler_mask).astype(theta.dtype)
        # Weighted least squares that zeroes out straggler rows:
        Gw = G * avail[:, None]
        Zw = Z * avail[None, :]

        def solve(zb):
            sol, *_ = jnp.linalg.lstsq(Gw, zb)
            return sol  # (K,) = M_{P_i} theta

        Mtheta = jax.vmap(solve)(Zw).reshape(-1)  # (k,)
        return Mtheta - self.b, jnp.int32(0)

    def step(self, theta, straggler_mask):
        g, aux = self.gradient(theta, straggler_mask)
        return self.projection(theta - self.lr * g), aux


@dataclasses.dataclass(frozen=True)
class Scheme2Blocked:
    """Scheme 2 generalized to k > K (paper footnote 2): the k rows of M are
    partitioned into k/K blocks, each encoded with the SAME (N=w, K) code;
    worker j holds row j of every block (α = k/K rows) and returns α scalars.

    Because a straggler erases the same coordinate of EVERY block's codeword,
    all k/K codewords share one erasure pattern — the decode is one batched
    peeling pass with payload width k/K (the decoder is payload-batched).
    This is the configuration of the paper's experiments: a (40, 20) code
    with k ∈ {200, ..., 2000}.
    """

    code: LDPCCode
    C_blocks: jax.Array  # (k/K, N, k)
    b: jax.Array         # (k,)
    lr: float
    decode_iters: int = 10
    decode_backend: str = "auto"  # dense | sparse | pallas | auto (decoder.py)
    projection: Callable[[jax.Array], jax.Array] = projections.identity

    @classmethod
    def build(cls, code: LDPCCode, moments: Moments, *, lr: float, **kw):
        return cls(code=code, C_blocks=encode_moment_blocks(code, moments.M),
                   b=moments.b, lr=lr, **kw)

    @property
    def w(self) -> int:
        return self.code.N

    def gradient(self, theta: jax.Array, straggler_mask: jax.Array):
        K = self.code.K
        nb = self.C_blocks.shape[0]
        Z = jnp.einsum("bnk,k->nb", self.C_blocks, theta)  # (N, k/K)
        Z = jnp.where(straggler_mask[:, None], 0.0, Z)
        dec = peel_decode(self.code, Z, straggler_mask, self.decode_iters,
                          backend=self.decode_backend)
        unresolved_rows = dec.erased[:K]             # same for every block
        c_hat = jnp.where(unresolved_rows[:, None], 0.0, dec.values[:K])  # (K, nb)
        # block b's rows are M[b*K:(b+1)*K] -> flat coordinate j = b*K + r
        c_flat = c_hat.T.reshape(-1)                 # (k,)
        unresolved_flat = jnp.tile(unresolved_rows, nb)
        b_hat = jnp.where(unresolved_flat, 0.0, self.b)
        return c_flat - b_hat, unresolved_flat.sum()

    def step(self, theta, straggler_mask):
        g, aux = self.gradient(theta, straggler_mask)
        return self.projection(theta - self.lr * g), aux


def run_pgd(
    scheme,
    theta0: jax.Array,
    straggler_model,
    steps: int,
    *,
    key: jax.Array | None = None,
    theta_star: jax.Array | None = None,
    loss_fn: Callable[[jax.Array], jax.Array] | None = None,
) -> RunResult:
    """Generic driver: sample straggler mask, take a coded step, track error.

    Jit-compiled as a single ``lax.scan`` over steps — the whole optimization
    trajectory runs on-device.
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    w = scheme.w

    def metric(theta):
        if theta_star is not None:
            return jnp.linalg.norm(theta - theta_star)
        if loss_fn is not None:
            return loss_fn(theta)
        return jnp.linalg.norm(theta)

    @jax.jit
    def scan_all(theta0, key):
        def body(carry, key_t):
            theta, tbar, t = carry
            mask = straggler_model.sample(key_t, w)
            theta2, unresolved = scheme.step(theta, mask)
            tbar2 = (tbar * t + theta2) / (t + 1.0)
            return (theta2, tbar2, t + 1.0), (metric(theta2), unresolved)

        keys = jax.random.split(key, steps)
        (theta, tbar, _), (errs, unres) = jax.lax.scan(
            body, (theta0, jnp.zeros_like(theta0), 0.0), keys
        )
        return theta, tbar, errs, unres

    theta, tbar, errs, unres = scan_all(theta0, key)
    return RunResult(theta, tbar, errs, unres)
