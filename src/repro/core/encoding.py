"""Moment encoding (the paper's preprocessing step).

Given data ``X in R^{m x k}`` and labels ``y in R^m``, the gradient of the
squared loss is ``∇L(θ) = M θ - b`` with ``M = X^T X`` and ``b = X^T y``.
``M`` is computed ONCE and encoded:

* Scheme 2 (``K == k``): ``C = G @ M in R^{N x k}``; worker ``j`` stores row
  ``c_j`` and computes the scalar ``⟨c_j, θ⟩`` per step.  ``C θ`` is a
  codeword whose first ``k`` coordinates are ``M θ`` (systematic G).

* Scheme 1 (``K | k``): the rows of ``M`` are partitioned into ``k/K``
  blocks, each encoded separately: ``C^(i) = G M_{P_i}``; worker ``j`` holds
  row ``j`` of every block (α = k/K rows total) and returns α scalars.

Encoding cost is one (N x K) @ (K x k) matmul — the Pallas ``block_matmul``
kernel covers this at scale; here the jnp path is the reference.

SEEDED encode: for a seeded LDGM code (:func:`repro.core.ldpc.make_seeded_ldgm`)
the generator rows are recomputable from ``(seed, row)`` in O(row_weight), so
``C = G @ M`` reduces to per-row gathers over M (:func:`encode_moment_seeded`)
and the per-step codeword ``C θ`` to a gather over ``y = M θ``
(:func:`gather_encode`) — no generator or encoding-matrix rows are ever
materialized.  The same gather tables drive the sharded worker encode
(``distributed/worker.local_products_seeded``), so single-device and
distributed products are bit-identical.

FUSED seeded encode (:func:`encode_seeded`): the gather itself moves into a
Pallas kernel (``encode_seeded_fused``) that regenerates each row's
(column, weight) pairs in-register, so not even the ``(N, r+1)`` index
tables exist.  :func:`gather_encode` runs its sum SEQUENTIALLY in table
order for exactly this reason: under jit, XLA:CPU contracts each
multiply-add into an FMA the same way inside and outside the kernel, so the
fused kernel is bit-identical to the jit-compiled table gather (a
``(g * c).sum(axis=1)`` reduction would sum in a different association
order and only match to ~1 ulp).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.ldpc import (LDPCCode, seeded_generator_rows,
                             seeded_structure)

__all__ = ["Moments", "second_moment", "encode_moment",
           "encode_moment_blocks", "encode_moment_seeded", "gather_encode",
           "generator_gather_tables", "encode_seeded",
           "generator_structure_of"]


class Moments(NamedTuple):
    M: jax.Array  # (k, k)
    b: jax.Array  # (k,)


def second_moment(X: jax.Array, y: jax.Array) -> Moments:
    """M = X^T X, b = X^T y — the one-time preprocessing pass."""
    X = jnp.asarray(X)
    y = jnp.asarray(y)
    return Moments(X.T @ X, X.T @ y)


def encode_moment(code: LDPCCode, M: jax.Array) -> jax.Array:
    """Scheme 2 encode: C = G @ M, shape (N, k); requires code.K == k."""
    M = jnp.asarray(M)
    if code.K != M.shape[0]:
        raise ValueError(f"code dimension K={code.K} != k={M.shape[0]}; "
                         "use encode_moment_blocks for K | k")
    G = jnp.asarray(code.G, M.dtype)
    return G @ M


def encode_moment_blocks(code: LDPCCode, M: jax.Array) -> jax.Array:
    """Scheme 1 encode: stack of per-block codeword matrices.

    Returns ``C`` of shape (k/K, N, k): ``C[i] = G @ M[i*K:(i+1)*K]``.
    Worker ``j`` is assigned ``C[:, j, :]`` (α = k/K rows).
    """
    M = jnp.asarray(M)
    k = M.shape[0]
    if k % code.K != 0:
        raise ValueError(f"K={code.K} must divide k={k}")
    nb = k // code.K
    G = jnp.asarray(code.G, M.dtype)
    blocks = M.reshape(nb, code.K, k)
    return jnp.einsum("nk,bkj->bnj", G, blocks)


def generator_gather_tables(code: LDPCCode) -> tuple[jax.Array, jax.Array]:
    """Full-generator gather tables of a seeded LDGM code, as jnp arrays.

    ``(idx (N, row_weight) int32, coeff (N, row_weight) f32)`` with
    ``G[i] = Σ_s coeff[i, s]·e_{idx[i, s]}`` — the whole generator in
    ``O(N·row_weight)`` ints instead of an ``(N, K)`` dense matrix.
    """
    idx, coeff = seeded_generator_rows(code, 0, code.N)
    return jnp.asarray(idx), jnp.asarray(coeff)


def gather_encode(idx: jax.Array, coeff: jax.Array,
                  y: jax.Array) -> jax.Array:
    """THE seeded per-row encode: ``z[i] = Σ_s coeff[i, s] · y[idx[i, s]]``.

    ``y`` is ``(K,)`` or ``(K, V)``; returns ``(n,)`` / ``(n, V)`` for
    tables of ``n`` rows.  Zero-weight pad slots gather row ``idx=0`` with
    coefficient 0 — exact zeros, no sentinel row needed.  Single-device
    encodes and each sharded worker's fused encode-matvec run this same
    gather+sum over their row ranges, so their products are bit-identical.

    The sum is SEQUENTIAL in table-slot order: under jit this lowers to
    the same FMA chain as the fused Pallas encode kernel
    (``kernels.ldpc_peel.encode_seeded_fused``), making the two
    bit-identical — the load-bearing property behind every
    materialized-vs-fused encode parity check.
    """
    yj = jnp.asarray(y)
    c = coeff.astype(yj.dtype)
    if yj.ndim == 2:
        c = c[..., None]
    out = c[:, 0] * yj[idx[:, 0]]
    for s in range(1, idx.shape[1]):
        out = out + c[:, s] * yj[idx[:, s]]
    return out


def encode_moment_seeded(code: LDPCCode, M: jax.Array) -> jax.Array:
    """Scheme 2 encode ``C = G @ M`` via the seeded generator gathers.

    Same shape contract as :func:`encode_moment` (``(N, k)``, requires
    ``code.K == k``) but the generator is never materialized: each codeword
    row is a ``row_weight``-term gather+sum over rows of ``M`` —
    ``O(N·row_weight·k)`` work and ``O(N·row_weight)`` structure ints
    instead of an ``(N, K)`` dense ``G``.  Requires a
    :func:`repro.core.ldpc.make_seeded_ldgm` code.
    """
    M = jnp.asarray(M)
    if code.K != M.shape[0]:
        raise ValueError(f"code dimension K={code.K} != k={M.shape[0]}; "
                         "use encode_moment_blocks for K | k")
    idx, coeff = generator_gather_tables(code)
    return gather_encode(idx, coeff, M)


def generator_structure_of(code: LDPCCode):
    """The :class:`repro.core.ldpc.SeededStructure` of a seeded LDGM code's
    generator parity block ``P`` (``G = [I; P]``) — the static spec the
    fused encode kernel regenerates rows from."""
    kind = getattr(code, "kind", None)
    if kind != "ldgm-seeded":
        raise ValueError(
            f"fused seeded encode needs a make_seeded_ldgm code "
            f"(kind='ldgm-seeded'); got kind={kind!r}")
    return seeded_structure(code.p, code.K, code.r - 1, code.seed)


def encode_seeded(code: LDPCCode, y: jax.Array, row0=0, *,
                  n_out: int | None = None,
                  interpret: bool | None = None) -> jax.Array:
    """Codeword rows ``[row0, row0 + n_out)`` of ``G @ y`` via the FUSED
    seeded encode kernel — no gather tables, no generator.

    ``y`` is ``(K,)`` or ``(K, V)``; ``row0`` may be traced (sharded
    workers pass their row offset); ``n_out`` defaults to the full
    codeword ``N``.  Bit-identical to the (jit-compiled)
    :func:`gather_encode` over :func:`generator_gather_tables` rows —
    see the module docstring for why the summation orders agree.
    """
    from repro.kernels.ldpc_peel.ops import encode_seeded_fused_pallas
    st = generator_structure_of(code)
    if n_out is None:
        n_out = code.N
    return encode_seeded_fused_pallas(st, y, row0, n_out=n_out,
                                      interpret=interpret)
