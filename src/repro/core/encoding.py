"""Moment encoding (the paper's preprocessing step).

Given data ``X in R^{m x k}`` and labels ``y in R^m``, the gradient of the
squared loss is ``∇L(θ) = M θ - b`` with ``M = X^T X`` and ``b = X^T y``.
``M`` is computed ONCE and encoded:

* Scheme 2 (``K == k``): ``C = G @ M in R^{N x k}``; worker ``j`` stores row
  ``c_j`` and computes the scalar ``⟨c_j, θ⟩`` per step.  ``C θ`` is a
  codeword whose first ``k`` coordinates are ``M θ`` (systematic G).

* Scheme 1 (``K | k``): the rows of ``M`` are partitioned into ``k/K``
  blocks, each encoded separately: ``C^(i) = G M_{P_i}``; worker ``j`` holds
  row ``j`` of every block (α = k/K rows total) and returns α scalars.

Encoding cost is one (N x K) @ (K x k) matmul — the Pallas ``block_matmul``
kernel covers this at scale; here the jnp path is the reference.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.ldpc import LDPCCode

__all__ = ["Moments", "second_moment", "encode_moment", "encode_moment_blocks"]


class Moments(NamedTuple):
    M: jax.Array  # (k, k)
    b: jax.Array  # (k,)


def second_moment(X: jax.Array, y: jax.Array) -> Moments:
    """M = X^T X, b = X^T y — the one-time preprocessing pass."""
    X = jnp.asarray(X)
    y = jnp.asarray(y)
    return Moments(X.T @ X, X.T @ y)


def encode_moment(code: LDPCCode, M: jax.Array) -> jax.Array:
    """Scheme 2 encode: C = G @ M, shape (N, k); requires code.K == k."""
    M = jnp.asarray(M)
    if code.K != M.shape[0]:
        raise ValueError(f"code dimension K={code.K} != k={M.shape[0]}; "
                         "use encode_moment_blocks for K | k")
    G = jnp.asarray(code.G, M.dtype)
    return G @ M


def encode_moment_blocks(code: LDPCCode, M: jax.Array) -> jax.Array:
    """Scheme 1 encode: stack of per-block codeword matrices.

    Returns ``C`` of shape (k/K, N, k): ``C[i] = G @ M[i*K:(i+1)*K]``.
    Worker ``j`` is assigned ``C[:, j, :]`` (α = k/K rows).
    """
    M = jnp.asarray(M)
    k = M.shape[0]
    if k % code.K != 0:
        raise ValueError(f"K={code.K} must divide k={k}")
    nb = k // code.K
    G = jnp.asarray(code.G, M.dtype)
    blocks = M.reshape(nb, code.K, k)
    return jnp.einsum("nk,bkj->bnj", G, blocks)
