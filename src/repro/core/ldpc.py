"""Real-valued LDPC / LDGM code construction for coded computation.

The paper (Maity, Rawat, Mazumdar 2018) encodes the second moment
``M = X^T X`` with an ``(N = w, K = k)`` systematic LDPC code over the reals.
Erasure decoding (stragglers = erasures) is done with the iterative peeling
decoder (see :mod:`repro.core.decoder`), whose behaviour is governed by the
``(l, r)``-regular degree structure of the parity-check matrix ``H``
(Proposition 2 / density evolution).

Two constructions are provided:

* :func:`make_regular_ldpc` — the paper's code: an ``(l, r)``-regular
  parity-check matrix ``H`` built with a configuration-model matching
  (exactly ``l`` nonzeros per column, ``r`` per row), Gaussian or ±1 edge
  weights, and a *systematic* generator ``G = [I_K ; -H2^{-1} H1]``.
  The dense parity block is fine here because the master encodes ``M``
  offline, once.

* :func:`make_ldgm` — a low-density *generator* matrix variant used by the
  beyond-paper coded gradient aggregation (:mod:`repro.core.grad_agg`),
  where each parity symbol must be computable by a single worker that only
  holds ``r - 1`` data shards, so the generator rows themselves must be
  sparse.  Its parity-check matrix is ``H = [P  -I]`` and the same peeling
  decoder applies.

Everything here is plain NumPy (host-side, offline preprocessing); the
per-step compute paths are JAX (see decoder.py / coded_step.py).

A third family is SEEDED: :func:`make_seeded_ldpc` /
:func:`make_seeded_ldgm` draw the same degree structure from a stateless
counter-based hash of ``(seed, row)``, so ``check_idx`` / ``check_coeff``
for ANY row range are recomputable in O(r) per row without the matrix —
the Pallas kernels regenerate H tiles in-register from the seed
(``backend="pallas_seeded"``), workers recompute their generator rows on
the fly, and million-row codes cost a seed instead of gigabytes.  See
:class:`SeededStructure` for the construction and the bit-exactness
contract between the NumPy and in-kernel generators.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Literal, NamedTuple

import numpy as np

__all__ = ["LDPCCode", "make_regular_ldpc", "make_ldgm",
           "make_parity_only_ldpc", "SeededStructure", "SeededLDPC",
           "make_seeded_ldpc", "make_seeded_ldgm", "seeded_structure",
           "seeded_structure_of", "seeded_check_rows", "seeded_h_rows",
           "seeded_generator_rows", "is_seeded"]


@dataclasses.dataclass(frozen=True)
class LDPCCode:
    """A systematic real-valued linear code defined by (H, G).

    Attributes:
      H: ``(p, N)`` parity-check matrix, ``H @ c = 0`` for codewords ``c``.
      G: ``(N, K)`` systematic generator, first ``K`` rows are ``I_K``.
      N: code length (== number of workers ``w`` in the paper's Scheme 2).
      K: code dimension (== model dimension ``k``).
      l: column weight of ``H`` (message columns for LDGM).
      r: row weight of ``H`` (excluding the identity part for LDGM).
      kind: "ldpc" (regular ensemble, dense parity block in G) or
        "ldgm" (sparse generator rows; H = [P, -I]).
      seed: construction seed (for reproducibility / re-derivation).
    """

    H: np.ndarray
    G: np.ndarray
    N: int
    K: int
    l: int
    r: int
    kind: str = "ldpc"
    seed: int = 0

    def __post_init__(self) -> None:
        # Build the neighbor table eagerly: every construction site is
        # offline/host-side, and the sparse decode backends assume the table
        # exists without a first-use hitch inside a timed hot path.
        self._neighbor_table  # noqa: B018 — cached_property warm-up

    @property
    def p(self) -> int:
        return self.N - self.K

    @property
    def rate(self) -> float:
        return self.K / self.N

    @property
    def H_mask(self) -> np.ndarray:
        """Boolean adjacency of the Tanner graph, shape (p, N)."""
        return self.H != 0.0

    @functools.cached_property
    def _neighbor_table(self) -> tuple[np.ndarray, np.ndarray]:
        """Padded CSR-like neighbor table of the Tanner graph.

        Returns ``(check_idx, check_coeff)``:

        * ``check_idx (p, r_max) int32`` — for check row ``i``, the column
          indices of its nonzero entries in ascending order, padded with the
          sentinel ``N`` (one past the last variable);
        * ``check_coeff (p, r_max) float32`` — the matching ``H[i, j]`` edge
          weights, padded with ``0.0``.

        ``r_max`` is the maximum row weight (== ``r`` for regular codes, so
        the table is dense: no padding waste).  Ascending column order makes
        the sparse flooding round pick the SAME erased neighbour as the dense
        round's ``argmax`` (first erased column), so the two backends follow
        identical decoding trajectories.  Built once per code (cached); the
        sentinel ``N`` lets JAX consumers gather from arrays padded by one
        row instead of branching.
        """
        mask = self.H != 0.0
        row_weights = mask.sum(axis=1)
        r_max = int(max(row_weights.max() if row_weights.size else 0, 1))
        p = self.H.shape[0]
        check_idx = np.full((p, r_max), self.N, dtype=np.int32)
        check_coeff = np.zeros((p, r_max), dtype=np.float32)
        for i in range(p):
            cols = np.flatnonzero(mask[i])  # ascending
            check_idx[i, : cols.size] = cols
            check_coeff[i, : cols.size] = self.H[i, cols]
        return check_idx, check_coeff

    @property
    def check_idx(self) -> np.ndarray:
        """(p, r_max) int32 neighbor columns per check, sentinel-padded with N."""
        return self._neighbor_table[0]

    @property
    def check_coeff(self) -> np.ndarray:
        """(p, r_max) float32 edge weights matching :attr:`check_idx`."""
        return self._neighbor_table[1]

    @functools.cached_property
    def _var_table(self) -> np.ndarray:
        """Column-side (variable → incident checks) table of the Tanner graph.

        ``(N, l_max) int32`` — for variable ``j``, the rows of its nonzero
        entries in ascending order, padded with the sentinel ``p`` (one past
        the last check).  ``l_max`` is the maximum column weight (== ``l``
        for regular codes).  This is the gather table the scatter-free
        batched decode round uses for its variable-side update (XLA scatters
        are the slow op on CPU; gathering each variable's ≤ l_max candidate
        resolutions is not).
        """
        mask = self.H != 0.0
        col_weights = mask.sum(axis=0)
        l_max = int(max(col_weights.max() if col_weights.size else 0, 1))
        p = self.H.shape[0]
        var_idx = np.full((self.N, l_max), p, dtype=np.int32)
        for j in range(self.N):
            rows = np.flatnonzero(mask[:, j])  # ascending
            var_idx[j, : rows.size] = rows
        return var_idx

    @property
    def var_idx(self) -> np.ndarray:
        """(N, l_max) int32 incident check rows per variable, sentinel ``p``."""
        return self._var_table

    def encode(self, message: np.ndarray) -> np.ndarray:
        """Encode a (K, ...) message block into an (N, ...) codeword block."""
        if self.G.size == 0:
            raise ValueError(
                "this code was built parity-only (make_parity_only_ldpc): "
                "it carries H for decode-structure work but no generator — "
                "use make_regular_ldpc when you need to encode")
        return self.G @ message

    def check(self, codeword: np.ndarray, atol: float = 1e-4) -> bool:
        """True iff ``codeword`` satisfies all parity checks."""
        return bool(np.allclose(self.H @ codeword, 0.0, atol=atol))


def _configuration_model(
    p: int, n: int, l: int, r: int, rng: np.random.Generator, max_fix_rounds: int = 10_000
) -> np.ndarray:
    """Random simple (l, r)-biregular bipartite graph via stub matching.

    Returns a boolean (p, n) adjacency with exactly ``l`` ones per column and
    ``r`` ones per row.  Double edges from the random matching are repaired
    with random edge swaps (standard configuration-model cleanup).
    """
    assert n * l == p * r, f"degree mismatch: n*l={n * l} != p*r={p * r}"
    # Edge list: column stubs in order, row stubs permuted.
    col_stubs = np.repeat(np.arange(n), l)
    row_stubs = np.repeat(np.arange(p), r)
    rng.shuffle(row_stubs)

    def dup_indices(rows: np.ndarray, cols: np.ndarray) -> np.ndarray:
        keys = rows.astype(np.int64) * n + cols
        order = np.argsort(keys, kind="stable")
        sorted_keys = keys[order]
        dup_sorted = np.concatenate([[False], sorted_keys[1:] == sorted_keys[:-1]])
        out = np.zeros_like(dup_sorted)
        out[order] = dup_sorted
        return np.nonzero(out)[0]

    rows, cols = row_stubs, col_stubs.copy()
    for _ in range(max_fix_rounds):
        dups = dup_indices(rows, cols)
        if dups.size == 0:
            break
        # Swap each duplicate edge's row endpoint with a random other edge,
        # sequentially (simultaneous fancy-index swaps with overlapping
        # indices would corrupt the degree multiset).
        for d in dups:
            partner = int(rng.integers(0, rows.size))
            rows[d], rows[partner] = rows[partner], rows[d]
    else:  # pragma: no cover - extremely unlikely for sane (l, r)
        raise RuntimeError("configuration model failed to produce a simple graph")

    adj = np.zeros((p, n), dtype=bool)
    adj[rows, cols] = True
    assert (adj.sum(axis=0) == l).all() and (adj.sum(axis=1) == r).all()
    return adj


def _edge_weights(
    adj: np.ndarray, rng: np.random.Generator, values: Literal["gaussian", "pm1"]
) -> np.ndarray:
    w = rng.standard_normal(adj.shape).astype(np.float64)
    if values == "pm1":
        w = np.sign(w) + (w == 0.0)
    return np.where(adj, w, 0.0)


def _pivot_columns(H: np.ndarray, p: int) -> np.ndarray | None:
    """Greedy rank-revealing column selection (LU with column pivoting).

    Returns ``p`` column indices of ``H`` (p x N) forming a well-conditioned
    square basis, or None if H is rank-deficient.
    """
    R = H.astype(np.float64).copy()
    n = R.shape[1]
    available = np.ones(n, dtype=bool)
    chosen: list[int] = []
    for i in range(p):
        norms = np.linalg.norm(R[i:, :], axis=0)
        norms[~available] = -1.0
        j = int(np.argmax(norms))
        if norms[j] <= 1e-10:
            return None
        # Row pivot to maximize |R[i, j]| for stability.
        pr = i + int(np.argmax(np.abs(R[i:, j])))
        if pr != i:
            R[[i, pr]] = R[[pr, i]]
        chosen.append(j)
        available[j] = False
        piv = R[i, j]
        if i + 1 < p:
            R[i + 1 :] -= np.outer(R[i + 1 :, j] / piv, R[i])
    return np.array(chosen)


def make_regular_ldpc(
    K: int,
    *,
    l: int = 3,
    r: int = 6,
    seed: int = 0,
    values: Literal["gaussian", "pm1"] = "gaussian",
    max_seed_tries: int = 64,
) -> LDPCCode:
    """Construct the paper's (l, r)-regular systematic LDPC code over R.

    Code length ``N = K * r / (r - l)`` (rate ``1 - l/r``); the paper's
    experiments use a rate-1/2 ``(40, 20)`` code, i.e. ``l/r = 1/2``.

    The systematic generator is ``G = [I_K ; -H2^{-1} H1]`` where
    ``H = [H1 | H2]``; seeds are retried until ``H2`` is well-conditioned
    (generic for Gaussian edge weights on a random biregular graph).
    """
    if l >= r:
        raise ValueError(f"need l < r for positive rate, got l={l}, r={r}")
    if (K * l) % (r - l) != 0:
        raise ValueError(f"K*l must be divisible by (r-l); K={K}, l={l}, r={r}")
    p = K * l // (r - l)
    N = K + p
    assert N * l == p * r

    for trial in range(max_seed_tries):
        rng = np.random.default_rng(seed + 7919 * trial)
        adj = _configuration_model(p, N, l, r, rng)
        H = _edge_weights(adj, rng, values)
        # A FIXED set of p columns of a sparse biregular H is near-singular
        # with high probability at scale; pick the parity positions by
        # pivoted elimination (rank-revealing) and permute them to the back.
        # Column permutation preserves (l, r)-regularity; the code is
        # systematic in its own (permuted) coordinate order.
        parity_cols = _pivot_columns(H, p)
        if parity_cols is None:
            continue
        msg_cols = np.setdiff1d(np.arange(N), parity_cols, assume_unique=False)
        perm = np.concatenate([msg_cols, parity_cols])
        H = H[:, perm]
        H2 = H[:, K:]
        if np.linalg.cond(H2) > 1e7:
            continue
        P = -np.linalg.solve(H2, H[:, :K])  # (p, K)
        G = np.concatenate([np.eye(K), P], axis=0)
        code = LDPCCode(
            H=H.astype(np.float64),
            G=G.astype(np.float64),
            N=N,
            K=K,
            l=l,
            r=r,
            kind="ldpc",
            seed=seed + 7919 * trial,
        )
        assert np.allclose(code.H @ code.G, 0.0, atol=1e-6 * np.abs(H).max() * K)
        return code
    raise RuntimeError(f"no well-conditioned H2 found in {max_seed_tries} tries")


def make_parity_only_ldpc(
    K: int,
    *,
    l: int = 3,
    r: int = 6,
    seed: int = 0,
    values: Literal["gaussian", "pm1"] = "gaussian",
) -> LDPCCode:
    """(l, r)-regular parity structure WITHOUT the systematic generator.

    :func:`make_regular_ldpc`'s generator solve (rank-revealing column
    pivoting + the dense ``H2^{-1} H1`` block) is O(p²·N) and dominates
    construction past N ≈ 4096 — but the peeling DECODE trajectory depends
    only on ``H`` and the erasure mask, never on the payload being a
    codeword.  Large-N decoder benchmarks and tests (the check-axis-tiled
    kernels, the sharded master decode) therefore use this constructor:
    the same configuration-model ``H`` (f32 to halve the footprint at
    N = 16384), neighbor/column tables as usual, and an EMPTY generator —
    :meth:`LDPCCode.encode` raises with a pointer back to
    :func:`make_regular_ldpc`.
    """
    if l >= r:
        raise ValueError(f"need l < r for positive rate, got l={l}, r={r}")
    if (K * l) % (r - l) != 0:
        raise ValueError(f"K*l must be divisible by (r-l); K={K}, l={l}, r={r}")
    p = K * l // (r - l)
    N = K + p
    rng = np.random.default_rng(seed)
    adj = _configuration_model(p, N, l, r, rng)
    w = rng.standard_normal(adj.shape, dtype=np.float32)
    if values == "pm1":
        w = np.sign(w) + (w == 0.0)
    H = np.where(adj, w, 0.0).astype(np.float32)
    return LDPCCode(H=H, G=np.zeros((N, 0), np.float32), N=N, K=K, l=l, r=r,
                    kind="ldpc-parity-only", seed=seed)


def make_ldgm(
    K: int,
    p: int,
    *,
    row_weight: int = 4,
    seed: int = 0,
    values: Literal["gaussian", "pm1"] = "pm1",
) -> LDPCCode:
    """Low-density generator matrix code: c = [m ; P m] with sparse P.

    Each of the ``p`` parity rows has exactly ``row_weight`` nonzeros, so a
    parity *worker* only needs ``row_weight`` message shards — this is the
    constraint for coded gradient aggregation where a worker can only hold a
    few data shards.  Column degrees are balanced (each message symbol
    participates in ``ceil/floor(p*row_weight/K)`` parities).

    Parity-check matrix: ``H = [P  -I_p]`` — note every parity column has
    degree 1, so the peeling decoder can always consume checks whose parity
    symbol is known.
    """
    if row_weight > K:
        raise ValueError("row_weight cannot exceed K")
    rng = np.random.default_rng(seed)
    # Balanced column assignment: deal message indices round-robin from a
    # shuffled deck so column degrees differ by at most 1.
    total = p * row_weight
    deck = []
    while len(deck) < total:
        perm = rng.permutation(K)
        deck.extend(perm.tolist())
    P = np.zeros((p, K), dtype=np.float64)
    idx = 0
    for i in range(p):
        chosen: set[int] = set()
        while len(chosen) < row_weight:
            cand = deck[idx % len(deck)]
            idx += 1
            if cand not in chosen:
                chosen.add(cand)
        cols = np.fromiter(chosen, dtype=int)
        w = rng.standard_normal(cols.size)
        if values == "pm1":
            w = np.sign(w) + (w == 0.0)
        P[i, cols] = w
    H = np.concatenate([P, -np.eye(p)], axis=1)
    G = np.concatenate([np.eye(K), P], axis=0)
    l_eff = int(round(total / K))
    return LDPCCode(
        H=H, G=G, N=K + p, K=K, l=max(l_eff, 1), r=row_weight + 1, kind="ldgm", seed=seed
    )


# ------------------------------------------------------------------ seeded --
#
# A deterministic, counter-based draw of the (l, r)-regular ensemble: the
# structure of any check row is a pure function of (seed, row), computable
# in O(r) integer ops with no state and no matrix.  The SAME function is
# implemented twice — here in NumPy (the materializing reference) and in
# jnp inside kernels/ldpc_peel/kernel.py (the in-register tile generator) —
# and the two are bit-exact: every op is 32-bit integer arithmetic plus
# float32 steps that are exact in IEEE-754 (integer-to-float of < 2^23
# values, scaling by powers of two, adding 1.0 to a 23-bit fraction).
#
# Construction ("layered permutations"): the `rows` check rows split into
# `layers` layers of `rows_per_layer = cols / row_weight` rows each.  Layer
# t carries an affine permutation x -> (a_t * x + b_t) mod cols (a_t coprime
# to cols, drawn from the seed); row j of the layer covers the r-slice
# pi_t[j*r : (j+1)*r].  Each layer therefore covers every column EXACTLY
# once, so the ensemble is exactly (layers, row_weight)-biregular — the same
# degree profile as the configuration model, by construction rather than by
# repair.  a_t is bounded by 2^31 / cols so a_t * x + b_t never leaves
# int32, which is what lets the kernel run the identical arithmetic on TPU.
#
# Edge weights: w = sign * (1 + m * 2^-23) with (sign, m) drawn from a
# lowbias32-style avalanche hash of the global edge counter row*r + s.
# Magnitudes live in [1, 2) — never zero, well-conditioned for the peeling
# division — and every step is exact in f32, so host and kernel agree bit
# for bit.

_W_MULT1 = 0x7FEB352D          # lowbias32 multipliers (Ettinger)
_W_MULT2 = 0x846CA68B


def _mix32(x: np.ndarray) -> np.ndarray:
    """Stateless avalanche hash on uint32 arrays (numpy reference)."""
    with np.errstate(over="ignore"):     # uint32 wraparound is the point
        x = x.astype(np.uint32)
        x = x ^ (x >> np.uint32(16))
        x = x * np.uint32(_W_MULT1)
        x = x ^ (x >> np.uint32(15))
        x = x * np.uint32(_W_MULT2)
        x = x ^ (x >> np.uint32(16))
    return x


def _host_hash(*counters: int) -> int:
    """Fold integer counters through the mix — host-side param derivation."""
    h = np.uint32(0x9E3779B9)
    for c in counters:
        h = _mix32(h ^ np.uint32(c & 0xFFFFFFFF))
    return int(h)


class SeededStructure(NamedTuple):
    """The complete seed-derived description of a sparse biregular block.

    Hashable and made of plain ints/tuples, so kernels can take it as a
    STATIC argument: baking the per-layer affine constants into the compiled
    kernel is what makes in-register tile regeneration free of operands.

    ``rows x cols`` with exactly ``row_weight`` nonzeros per row and exactly
    ``layers = rows * row_weight / cols`` per column.
    """

    rows: int
    cols: int
    row_weight: int
    layers: int
    rows_per_layer: int
    seed: int
    strides: tuple[int, ...]       # a_t per layer, gcd(a_t, cols) == 1
    offsets: tuple[int, ...]       # b_t per layer, in [0, cols)
    wseed: int                     # uint32 salt for the edge-weight hash


def seeded_structure(rows: int, cols: int, row_weight: int,
                     seed: int) -> SeededStructure:
    """Derive the full structure (layer constants included) from the seed.

    Requires ``cols % row_weight == 0`` (each layer's rows partition the
    columns into ``cols / row_weight`` slices) and
    ``rows % (cols // row_weight) == 0`` (whole layers).
    """
    if row_weight <= 0 or rows <= 0 or cols <= 0:
        raise ValueError("rows, cols, row_weight must be positive")
    if cols % row_weight != 0:
        raise ValueError(
            f"seeded structure needs cols % row_weight == 0 (layered "
            f"permutations partition the columns); got cols={cols}, "
            f"row_weight={row_weight} — pick a row weight dividing the "
            f"code length (e.g. the (4, 8) ensemble for power-of-two N)")
    rows_per_layer = cols // row_weight
    if rows % rows_per_layer != 0:
        raise ValueError(
            f"seeded structure needs whole layers: rows={rows} is not a "
            f"multiple of cols/row_weight={rows_per_layer}")
    layers = rows // rows_per_layer
    # a_t bounded so a_t * x + b_t stays inside int32 for every x < cols —
    # the contract that lets the kernel run the identical arithmetic.
    amax = max(1, min((2**31 - cols) // cols, 1 << 20))
    strides, offsets = [], []
    for t in range(layers):
        a = 1
        for trial in range(256):
            cand = 1 + _host_hash(seed, t, trial, 0xA11CE) % amax
            if math.gcd(cand, cols) == 1:
                a = cand
                break
        strides.append(a)
        offsets.append(_host_hash(seed, t, 0xB0FFE) % cols)
    return SeededStructure(rows=rows, cols=cols, row_weight=row_weight,
                           layers=layers, rows_per_layer=rows_per_layer,
                           seed=seed, strides=tuple(strides),
                           offsets=tuple(offsets),
                           wseed=_host_hash(seed, 0x5EED5))


def _structure_rows_raw(st: SeededStructure, lo: int, hi: int):
    """(cols, coeffs) of rows [lo, hi) in DRAW order (slot order, unsorted).

    O(row_weight) integer ops per row; this is the materializing reference
    the in-kernel generator is tested bit-exact against.
    """
    if not (0 <= lo <= hi <= st.rows):
        raise ValueError(f"row range [{lo}, {hi}) outside [0, {st.rows})")
    rows = np.arange(lo, hi, dtype=np.int64)[:, None]       # (n, 1)
    s = np.arange(st.row_weight, dtype=np.int64)[None, :]   # (1, r)
    t = rows // st.rows_per_layer
    jl = rows - t * st.rows_per_layer
    a = np.asarray(st.strides, dtype=np.int64)[t]
    b = np.asarray(st.offsets, dtype=np.int64)[t]
    cols = (a * (jl * st.row_weight + s) + b) % st.cols     # < 2^31 by amax
    edge = (rows * st.row_weight + s).astype(np.uint32)     # global counter
    u = _mix32(edge ^ np.uint32(st.wseed))
    sign = np.float32(1.0) - np.float32(2.0) * (u & np.uint32(1)).astype(np.float32)
    m = (u >> np.uint32(9)).astype(np.int32).astype(np.float32)  # [0, 2^23)
    w = sign * (np.float32(1.0) + m * np.float32(2.0 ** -23))    # exact f32
    return cols.astype(np.int32), w.astype(np.float32)


def seeded_check_rows(st: SeededStructure, lo: int, hi: int):
    """``(check_idx, check_coeff)`` for rows [lo, hi): ``(n, row_weight)``
    int32 columns in ASCENDING order (the neighbor-table convention, so the
    sparse backends see the same tables as :attr:`LDPCCode.check_idx`) with
    the matching float32 edge weights."""
    cols, w = _structure_rows_raw(st, lo, hi)
    order = np.argsort(cols, axis=1, kind="stable")
    return (np.take_along_axis(cols, order, axis=1),
            np.take_along_axis(w, order, axis=1))


def seeded_h_rows(st: SeededStructure, lo: int, hi: int) -> np.ndarray:
    """Materialize dense float32 rows [lo, hi) of the seeded block."""
    cols, w = _structure_rows_raw(st, lo, hi)
    out = np.zeros((hi - lo, st.cols), dtype=np.float32)
    np.put_along_axis(out, cols.astype(np.int64), w, axis=1)
    return out


@dataclasses.dataclass(frozen=True)
class SeededLDPC:
    """Structure-only seeded (l, r)-regular code: NO materialized matrix.

    Carries exactly what :func:`make_seeded_ldpc` derives, minus the H it
    materializes — for code lengths where a dense ``(p, N)`` H would not
    fit in host memory at all.  Only ``backend="pallas_seeded"`` can decode
    it (the kernel regenerates tiles from the seed); anything that needs H
    or the full neighbor table should use :func:`make_seeded_ldpc`.
    """

    N: int
    K: int
    l: int
    r: int
    seed: int = 0
    kind: str = dataclasses.field(default="ldpc-seeded", init=False)

    def __post_init__(self) -> None:
        _validate_seeded_lr(self.K, self.l, self.r)

    @property
    def p(self) -> int:
        return self.N - self.K

    @property
    def rate(self) -> float:
        return self.K / self.N

    @property
    def structure(self) -> SeededStructure:
        return seeded_structure(self.p, self.N, self.r, self.seed)

    def check_rows(self, lo: int, hi: int):
        """O(r)-per-row ``(check_idx, check_coeff)`` for any row range."""
        return seeded_check_rows(self.structure, lo, hi)


def _validate_seeded_lr(K: int, l: int, r: int) -> int:
    if l >= r:
        raise ValueError(f"need l < r for positive rate, got l={l}, r={r}")
    if (K * l) % (r - l) != 0:
        raise ValueError(f"K*l must be divisible by (r-l); K={K}, l={l}, r={r}")
    p = K * l // (r - l)
    if (K + p) % r != 0:
        raise ValueError(
            f"seeded ensemble needs N % r == 0 (N={K + p}, r={r}): the "
            f"layered-permutation draw partitions the N columns into N/r "
            f"slices per layer — use e.g. the (4, 8) rate-1/2 ensemble for "
            f"power-of-two N, or pick K with r | N")
    return p


def make_seeded_ldpc(
    K: int,
    *,
    l: int = 4,
    r: int = 8,
    seed: int = 0,
) -> LDPCCode:
    """(l, r)-regular parity structure drawn from a counter-based seed.

    Same ensemble contract as :func:`make_parity_only_ldpc` (exactly ``r``
    nonzeros per check row, exactly ``l`` per column, real edge weights, no
    generator) but every row is a pure O(r) function of ``(seed, row)`` —
    see :func:`seeded_check_rows` — so kernels and workers can regenerate
    any slice of the structure instead of storing or streaming it.  H is
    materialized here (f32) so ALL existing backends run on the same code
    and the seeded kernel's bit-exactness has a reference; for lengths
    where even that is impossible use :class:`SeededLDPC`.

    The default ensemble is (4, 8): rate 1/2 like the paper's (3, 6), with
    a row weight that divides every power-of-two code length (the layered
    draw needs ``N % r == 0``; (3, 6) works too whenever 6 | N).
    """
    p = _validate_seeded_lr(K, l, r)
    N = K + p
    st = seeded_structure(p, N, r, seed)
    assert st.layers == l, (st.layers, l)    # p*r == N*l guarantees this
    H = seeded_h_rows(st, 0, p)
    return LDPCCode(H=H, G=np.zeros((N, 0), np.float32), N=N, K=K, l=l, r=r,
                    kind="ldpc-seeded", seed=seed)


def make_seeded_ldgm(
    K: int,
    p: int,
    *,
    row_weight: int = 8,
    seed: int = 0,
) -> LDPCCode:
    """Seeded low-density GENERATOR code: c = [m ; P m] with seeded P.

    The ``(p, K)`` parity block P is a seeded biregular structure (exactly
    ``row_weight`` per parity row, balanced column degrees), so a worker
    can compute its generator rows — hence its slice of ``C @ θ`` — from
    the seed alone, never holding encoding-matrix rows
    (:func:`repro.core.encoding.encode_moment_seeded` and
    ``distributed/worker.local_products_seeded`` are the consumers).
    Parity-check matrix ``H = [P  -I_p]`` as for :func:`make_ldgm`; the
    same peeling decoder applies.

    Needs ``K % row_weight == 0`` and ``p % (K // row_weight) == 0``
    (whole layers of the layered-permutation draw).
    """
    if row_weight > K:
        raise ValueError("row_weight cannot exceed K")
    st = seeded_structure(p, K, row_weight, seed)
    P = seeded_h_rows(st, 0, p).astype(np.float64)
    H = np.concatenate([P, -np.eye(p)], axis=1)
    G = np.concatenate([np.eye(K), P], axis=0)
    l_eff = max(int(round(p * row_weight / K)), 1)
    return LDPCCode(H=H, G=G, N=K + p, K=K, l=l_eff, r=row_weight + 1,
                    kind="ldgm-seeded", seed=seed)


def is_seeded(code) -> bool:
    """True if ``code`` carries a recomputable seeded structure."""
    return getattr(code, "kind", "") in ("ldpc-seeded", "ldgm-seeded")


def seeded_structure_of(code) -> SeededStructure:
    """The seeded H-structure of a code built by :func:`make_seeded_ldpc`
    or :class:`SeededLDPC` (the (p, N) regular block the decode kernels
    regenerate).  Raises for codes that do not carry a seed."""
    if getattr(code, "kind", "") != "ldpc-seeded":
        raise ValueError(
            f"backend='pallas_seeded' needs a seeded (l, r)-regular code "
            f"(make_seeded_ldpc / SeededLDPC); got kind="
            f"{getattr(code, 'kind', type(code).__name__)!r}")
    return seeded_structure(code.p, code.N, code.r, code.seed)


def seeded_generator_rows(code: LDPCCode, lo: int, hi: int):
    """Generator rows [lo, hi) of a seeded LDGM code as gather tables.

    Returns ``(idx (n, row_weight) int32, coeff (n, row_weight) f32)`` with
    ``G[i] = sum_s coeff[i, s] * e_{idx[i, s]}``: systematic rows (i < K)
    are ``[i, 0, 0, ...]`` with coeffs ``[1, 0, 0, ...]`` (the zero-weight
    pad keeps the gather shape uniform and adds exact zeros), parity rows
    are the seeded P rows in ascending column order.  One representation
    for the whole generator is what lets the single-device encode and the
    sharded worker encode run the SAME per-row gather+sum — bit-identical
    products.
    """
    if code.kind != "ldgm-seeded":
        raise ValueError(f"seeded generator rows need a make_seeded_ldgm "
                         f"code; got kind={code.kind!r}")
    if not (0 <= lo <= hi <= code.N):
        raise ValueError(f"row range [{lo}, {hi}) outside [0, {code.N})")
    rw = code.r - 1                       # LDGM kind stores r = row_weight+1
    st = seeded_structure(code.p, code.K, rw, code.seed)
    idx = np.zeros((hi - lo, rw), dtype=np.int32)
    coeff = np.zeros((hi - lo, rw), dtype=np.float32)
    n_sys = max(0, min(hi, code.K) - lo)
    if n_sys:
        idx[:n_sys, 0] = np.arange(lo, lo + n_sys, dtype=np.int32)
        coeff[:n_sys, 0] = 1.0
    if hi > code.K:
        plo, phi = max(lo, code.K) - code.K, hi - code.K
        idx[n_sys:], coeff[n_sys:] = seeded_check_rows(st, plo, phi)
    return idx, coeff
