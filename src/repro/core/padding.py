"""Shared zero-padding helpers for coded-compute data layout.

Every layer that tiles or partitions arrays used to re-derive the same two
idioms — "pad this axis up to a multiple of m" (kernel tile alignment) and
"split samples into equal blocks, zero-padding the tail" (worker data
partitioning).  They live here once; the engine, the schemes, and the Pallas
wrappers all import them.

Zero padding is exact for every consumer in this repo: padded sample rows
contribute nothing to ``X^T (X θ - y)``, and padded code coordinates sit on
all-zero ``H`` columns/rows so the peeling decoder never counts, resolves,
or writes them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["pad_axis_to", "pad_blocks"]


def pad_axis_to(x: jax.Array, multiple: int, axis: int) -> jax.Array:
    """Zero-pad ``axis`` of ``x`` up to the next multiple of ``multiple``."""
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def pad_blocks(X: jax.Array, y: jax.Array, parts: int) -> tuple[jax.Array, jax.Array]:
    """Split samples into ``parts`` equal blocks, zero-padding the tail.

    Zero rows contribute nothing to X^T(Xθ - y), so padding is exact (the
    paper's 40-worker / m=2048 setup has uneven partitions too).
    """
    m = X.shape[0]
    pad = (-m) % parts
    if pad:
        X = jnp.pad(X, ((0, pad), (0, 0)))
        y = jnp.pad(y, (0, pad))
    mp = m + pad
    return X.reshape(parts, mp // parts, -1), y.reshape(parts, mp // parts)
