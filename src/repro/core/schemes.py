"""Baseline straggler-mitigation schemes the paper compares against.

All schemes — the engine-backed paper schemes in
:mod:`repro.core.coded_step` and the baselines here — satisfy the
:class:`Scheme` Protocol (``.w``, ``.gradient(theta, mask)``,
``.step(theta, mask)``), so the same ``run_pgd`` driver and benchmark
harness drive every scheme (no ad-hoc duck typing; conformance is tested).
:func:`scheme_registry` enumerates them all:

* :class:`Uncoded` — w workers each hold m/w samples; the master sums the
  partial gradients that arrive (stragglers' contributions are simply lost).
* :class:`Replication` — r-fold replication of data partitions; a
  partition's gradient is lost only if ALL its replicas straggle.
* :class:`Karakus` — data encoding of Karakus et al. (NeurIPS'17): solve
  ``min ||S(y - Xθ)||²`` with an encoding matrix S (subsampled Hadamard or
  Gaussian); workers hold row-blocks of SX, Sy and return partial gradients
  of the encoded objective.
* :class:`MDSLee` — Lee et al.: two MDS-coded matvec rounds per step
  (u = Xθ then X^T u); exact recovery via least squares on surviving rows;
  exhibits the Vandermonde conditioning issue the paper criticizes.
* :class:`GradientCodingFR` — Tandon et al. fractional-repetition gradient
  coding: groups of (s+1) workers replicate a block set; exact for any s
  stragglers; each worker ships a k-vector.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.padding import pad_blocks as _pad_blocks
from repro.optim import projections

__all__ = ["Scheme", "scheme_registry", "Uncoded", "Replication", "Karakus",
           "MDSLee", "GradientCodingFR", "hadamard_matrix"]


@runtime_checkable
class Scheme(Protocol):
    """What ``run_pgd`` (and the benchmark harness) requires of a scheme.

    ``w`` is the worker count (the straggler-mask length);
    ``gradient(theta, straggler_mask)`` returns ``(g, aux)`` with ``g`` the
    (possibly approximate) gradient and ``aux`` a scalar decode-quality
    metric (|U_t| for coded schemes, lost-partition counts for baselines);
    ``step`` applies the projected update and passes ``aux`` through.

    Both the engine-backed paper schemes (``Scheme1``/``Scheme2``/
    ``Scheme2Blocked`` in :mod:`repro.core.coded_step`) and the baselines
    below satisfy it — ``isinstance(s, Scheme)`` works at runtime.
    """

    @property
    def w(self) -> int: ...

    def gradient(self, theta: jax.Array, straggler_mask: jax.Array
                 ) -> tuple[jax.Array, jax.Array]: ...

    def step(self, theta: jax.Array, straggler_mask: jax.Array
             ) -> tuple[jax.Array, jax.Array]: ...


def scheme_registry() -> dict[str, type]:
    """All scheme classes, paper + baselines, keyed by short name.

    Built lazily (the paper schemes live in :mod:`repro.core.coded_step`,
    which must stay import-independent of this module).
    """
    from repro.core.coded_step import Scheme1, Scheme2, Scheme2Blocked

    return {
        "scheme1": Scheme1,
        "scheme2": Scheme2,
        "scheme2-blocked": Scheme2Blocked,
        "uncoded": Uncoded,
        "replication": Replication,
        "karakus": Karakus,
        "mds-lee": MDSLee,
        "gradient-coding-fr": GradientCodingFR,
    }


@dataclasses.dataclass(frozen=True)
class Uncoded:
    X: jax.Array  # (m, k)
    y: jax.Array  # (m,)
    w: int
    lr: float
    projection: Callable = projections.identity

    def gradient(self, theta, straggler_mask):
        Xb, yb = _pad_blocks(self.X, self.y, self.w)
        resid = jnp.einsum("wmk,k->wm", Xb, theta) - yb  # (w, m/w)
        partial = jnp.einsum("wmk,wm->wk", Xb, resid)  # (w, k)
        alive = (~straggler_mask).astype(theta.dtype)
        return jnp.einsum("wk,w->k", partial, alive), jnp.int32(straggler_mask.sum())

    def step(self, theta, mask):
        g, aux = self.gradient(theta, mask)
        return self.projection(theta - self.lr * g), aux


@dataclasses.dataclass(frozen=True)
class Replication:
    """r-fold replication: partition p is held by workers {p, p + w/r, ...}."""

    X: jax.Array
    y: jax.Array
    w: int
    lr: float
    r: int = 2
    projection: Callable = projections.identity

    def __post_init__(self):
        assert self.w % self.r == 0

    def gradient(self, theta, straggler_mask):
        parts = self.w // self.r
        Xb, yb = _pad_blocks(self.X, self.y, parts)
        resid = jnp.einsum("pmk,k->pm", Xb, theta) - yb
        partial = jnp.einsum("pmk,pm->pk", Xb, resid)  # (parts, k)
        # replica r of partition p is worker p + r*parts
        alive = (~straggler_mask).reshape(self.r, parts)  # [replica, partition]
        covered = alive.any(axis=0).astype(theta.dtype)  # partition recovered?
        lost = parts - covered.sum()
        return jnp.einsum("pk,p->k", partial, covered), lost.astype(jnp.int32)

    def step(self, theta, mask):
        g, aux = self.gradient(theta, mask)
        return self.projection(theta - self.lr * g), aux


def hadamard_matrix(n: int) -> np.ndarray:
    """Sylvester Hadamard matrix, n a power of two, entries ±1/sqrt scale-free."""
    assert n & (n - 1) == 0 and n > 0, "n must be a power of two"
    H = np.array([[1.0]])
    while H.shape[0] < n:
        H = np.block([[H, H], [H, -H]])
    return H


@dataclasses.dataclass(frozen=True)
class Karakus:
    """Data encoding of Karakus et al.: workers hold blocks of (SX, Sy)."""

    SX: jax.Array  # (n_enc, k)
    Sy: jax.Array  # (n_enc,)
    w: int
    lr: float
    projection: Callable = projections.identity

    @classmethod
    def build(cls, X, y, w: int, *, lr: float, kind: str = "hadamard",
              redundancy: float = 2.0, seed: int = 0, **kw) -> "Karakus":
        m, _ = X.shape
        n_enc = int(m * redundancy)
        n_enc += (-n_enc) % w  # divisible by w
        if kind == "hadamard":
            npow = 1 << (max(n_enc, m) - 1).bit_length()
            Hm = hadamard_matrix(npow)
            rng = np.random.default_rng(seed)
            rows = rng.choice(npow, size=n_enc, replace=False)
            cols = rng.choice(npow, size=m, replace=False)
            S = Hm[np.ix_(rows, cols)] / np.sqrt(n_enc)
        elif kind == "gaussian":
            rng = np.random.default_rng(seed)
            S = rng.standard_normal((n_enc, m)) / np.sqrt(n_enc)
        else:
            raise ValueError(kind)
        S = jnp.asarray(S, X.dtype)
        return cls(SX=S @ X, Sy=S @ y, w=w, lr=lr, **kw)

    def gradient(self, theta, straggler_mask):
        Xb, yb = _pad_blocks(self.SX, self.Sy, self.w)
        resid = jnp.einsum("wmk,k->wm", Xb, theta) - yb
        partial = jnp.einsum("wmk,wm->wk", Xb, resid)
        alive = (~straggler_mask).astype(theta.dtype)
        return jnp.einsum("wk,w->k", partial, alive), jnp.int32(straggler_mask.sum())

    def step(self, theta, mask):
        g, aux = self.gradient(theta, mask)
        return self.projection(theta - self.lr * g), aux


def _vandermonde(n: int, k: int) -> np.ndarray:
    # Chebyshev evaluation points in [-1, 1]: the best-conditioned choice for
    # a real Vandermonde — and it STILL degrades exponentially in k, which is
    # precisely the noise-stability criticism the paper levels at MDS-coded
    # schemes (test_mds_lee_conditioning_degrades exhibits it).
    pts = np.cos(np.pi * (2 * np.arange(n) + 1) / (2 * n))
    return np.vander(pts, k, increasing=True)


@dataclasses.dataclass(frozen=True)
class MDSLee:
    """Lee et al. MDS-coded gradient descent: two coded matvecs per step."""

    X: jax.Array
    y: jax.Array
    w: int
    lr: float
    K_code: int  # MDS code dimension (number of systematic row blocks)
    projection: Callable = projections.identity

    @classmethod
    def build(cls, X, y, w: int, *, lr: float, K_code: int | None = None, **kw):
        if K_code is None:
            K_code = w // 2
        return cls(X=X, y=y, w=w, lr=lr, K_code=K_code, **kw)

    def _coded_matvec(self, A, v, mask):
        """Recover A @ v from surviving MDS-coded row-block products."""
        rows = A.shape[0]
        Kc = self.K_code
        pad = (-rows) % Kc
        Ap = jnp.pad(A, ((0, pad), (0, 0)))
        blocks = Ap.reshape(Kc, -1, A.shape[1])  # (Kc, rb, k)
        G = jnp.asarray(_vandermonde(self.w, Kc), A.dtype)  # (w, Kc)
        coded = jnp.einsum("wK,Krk->wrk", G, blocks)  # worker w holds coded block
        prods = jnp.einsum("wrk,k->wr", coded, v)  # worker products
        alive = (~mask).astype(A.dtype)
        Gw = G * alive[:, None]
        Pw = prods * alive[:, None]
        sol, *_ = jnp.linalg.lstsq(Gw, Pw)  # (Kc, rb) block products
        return sol.reshape(-1)[: rows]

    def gradient(self, theta, straggler_mask):
        # round 1: u = X theta; round 2: g = X^T u - X^T y
        u = self._coded_matvec(self.X, theta, straggler_mask)
        g = self._coded_matvec(self.X.T, u, straggler_mask) - self.X.T @ self.y
        return g, jnp.int32(straggler_mask.sum())

    def step(self, theta, mask):
        g, aux = self.gradient(theta, mask)
        return self.projection(theta - self.lr * g), aux


@dataclasses.dataclass(frozen=True)
class GradientCodingFR:
    """Tandon et al. gradient coding, fractional-repetition construction.

    Workers are split into w/(s+1) groups; all members of group g hold the
    same (s+1) data blocks and send the sum of their partial gradients.  Any
    one survivor per group suffices; exact for up to s stragglers per group
    (and for ANY s stragglers overall in the FR construction).
    """

    X: jax.Array
    y: jax.Array
    w: int
    s: int
    lr: float
    projection: Callable = projections.identity

    def __post_init__(self):
        assert self.w % (self.s + 1) == 0

    def gradient(self, theta, straggler_mask):
        groups = self.w // (self.s + 1)
        Xb, yb = _pad_blocks(self.X, self.y, groups)
        resid = jnp.einsum("gmk,k->gm", Xb, theta) - yb
        group_grad = jnp.einsum("gmk,gm->gk", Xb, resid)  # (groups, k)
        # worker j belongs to group j % groups; group alive if any member alive
        alive = (~straggler_mask).reshape(self.s + 1, groups).any(axis=0)
        lost = groups - alive.sum()
        g = jnp.einsum("gk,g->k", group_grad, alive.astype(theta.dtype))
        return g, lost.astype(jnp.int32)

    def step(self, theta, mask):
        g, aux = self.gradient(theta, mask)
        return self.projection(theta - self.lr * g), aux
