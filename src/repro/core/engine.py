"""Unified batched coded-compute engine: encode → erase → decode → epilogue.

The paper's pipeline — encode moments with an LDPC code, lose coordinates to
stragglers, peel-decode, zero-fill, update — used to be reimplemented in
every consumer (``Scheme2``/``Scheme2Blocked``, ``CodedAggregator``, the
launch-layer dry-run steps).  :class:`CodedComputeEngine` owns that pipeline
ONCE, as composable jit-able stages, and every consumer is a thin client:

======== ====================================================================
stage    what it does
======== ====================================================================
encode   ``symbols = G @ payload`` — systematic codeword(s) of the payload
         (the paper's offline moment encode, or per-step partial-gradient
         encode for coded aggregation).
erase    zero the straggled coordinates (workers that did not report).
decode   the peeling decode via :mod:`repro.core.decoder`'s backend matrix
         (dense / sparse neighbor-table / fused Pallas kernel — resident,
         check-axis tiled, or seed-regenerated "pallas_seeded"), fixed-D
         or adaptive early-exit.  The engine's ``code`` may be a
         structure-only :class:`repro.core.ldpc.SeededLDPC`: decode stages
         work unchanged (the seeded kernel needs no H), only ``encode``
         needs a materialized generator.
epilogue zero-fill the unresolved systematic coordinates (paper Scheme 2:
         both ``ĉ`` and ``b̂`` zeroed on the unresolved set keeps the
         gradient estimate an unbiased (1-q_D)-scaled gradient — Lemma 1).
======== ====================================================================

**The batch axis over independent erasure patterns is first-class**:
:meth:`CodedComputeEngine.decode_batch` (and :meth:`recover_batch`) run B
concurrent coded queries — each with its OWN straggler realization — in one
launch, via a vmapped sparse/dense flooding loop or the batched fused Pallas
kernel (grid over the batch, H resident in VMEM and shared).  The batch
axis carries PER-SLOT adaptive state (``adaptive=True`` / per-slot
``budgets``): every slot early-exits at its own fixpoint and reports its
own round count, so decoding effort tracks each query's realized straggler
load instead of the batch's worst case.  This is the primitive that serves
heavy concurrent coded traffic (:mod:`repro.serving.coded_queries`'s
continuous-admission slot server) and that every later scaling layer
(sharded decode, async serving, multi-code support) builds on.

The payload axis ``V`` (many codewords sharing ONE erasure pattern — the
paper's blocked Scheme 2, where one straggler erases the same coordinate of
every block) and the pattern axis ``B`` (many independent erasure patterns)
are orthogonal; the engine exposes both.
"""
from __future__ import annotations

import dataclasses
import logging

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.decoder import (
    SEEDED_MODES,
    DecodeResult,
    peel_decode,
    peel_decode_adaptive,
    peel_decode_batch,
    peel_decode_batch_adaptive,
    pick_tile_bp,
    resolve_backend,
    vmem_bytes_estimate,
)
from repro.core.ldpc import LDPCCode
from repro.obs import metrics as _obs_metrics

__all__ = ["CodedComputeEngine", "blocked_epilogue"]

logger = logging.getLogger(__name__)


def blocked_epilogue(values: jax.Array, erased: jax.Array, b: jax.Array,
                     *, K: int, nb: int) -> tuple[jax.Array, jax.Array]:
    """Blocked-Scheme-2 epilogue: zero-fill + re-interleave + moment shift.

    ``values (N, nb)`` / ``erased (N,)`` come out of a payload-batched
    decode of ``nb`` blocks sharing one erasure pattern; block ``i`` holds
    rows ``M[i*K:(i+1)*K]``, so flat coordinate ``j = i*K + r``.  Returns
    ``(g, unresolved_flat)`` with ``g = ĉ - b̂`` the (k,) approximate
    gradient (both ``ĉ`` and ``b̂`` zeroed on the unresolved set) and
    ``unresolved_flat`` its (k,) bool unresolved mask.

    Shared by :class:`repro.core.coded_step.Scheme2Blocked` and the sharded
    launch-layer step builder (:func:`repro.launch.steps.build_coded_gd_step`)
    so the epilogue exists exactly once.
    """
    unresolved = erased[:K]                              # same for all blocks
    c_hat = jnp.where(unresolved[:, None], 0.0, values[:K])   # (K, nb)
    c_flat = c_hat.T.reshape(-1)                         # (k,)
    unresolved_flat = jnp.tile(unresolved, nb)
    b_hat = jnp.where(unresolved_flat, 0.0, b)
    return c_flat - b_hat, unresolved_flat


@dataclasses.dataclass(frozen=True)
class CodedComputeEngine:
    """One code + one decode policy, applied as composable pipeline stages.

    Construction is cheap (stores references); schemes build one per call
    site without jit-cache churn — the jitted stage functions are keyed on
    array shapes and the (static) backend/iteration knobs, not on engine
    identity.
    """

    code: LDPCCode
    decode_iters: int = 10
    # dense | sparse | pallas | pallas_tiled | pallas_seeded | replay | auto
    backend: str = "auto"
    adaptive: bool = False
    # backend="replay" only: the cross-pattern LRU of compiled peeling
    # schedules (repro.core.schedule_cache.ScheduleCache).  With a cache,
    # recurring straggler patterns pay the symbolic solve once and every
    # later decode is pure replay; without one the decode entry points
    # solve per call.  Replay dispatch needs CONCRETE erasure masks (the
    # schedule is a function of the pattern) — eager engine calls qualify,
    # jitted callers must pre-solve at dispatch time instead.
    schedule_cache: object | None = None
    # Tile plumbing for the check-axis-tiled fused kernels: bp (check-tile
    # height; None = sized from the VMEM budget) and bv (payload tile), plus
    # the VMEM budget "auto" dispatches on (None = decoder default, 8 MiB).
    bp: int | None = None
    bv: int | None = None
    vmem_budget_bytes: int | None = None
    # "pallas_seeded" round sub-dispatch: dense_tile | gather | auto
    # (the hwcaps FLOPs-crossover rule); ignored by other backends.
    seeded_mode: str = "dense_tile"

    def __post_init__(self) -> None:
        # Fail fast on unknown/unsupported backend names (same matrix as
        # decoder.resolve_backend) instead of at first decode, and record
        # the resolved dispatch where operators can see it.
        resolve_backend(self.backend, self.code, adaptive=self.adaptive,
                        vmem_budget_bytes=self.vmem_budget_bytes)
        if self.seeded_mode not in SEEDED_MODES:
            raise ValueError(f"unknown seeded_mode {self.seeded_mode!r}; "
                             f"want one of {SEEDED_MODES}")
        reg = _obs_metrics.active()
        if reg is not None:
            # The dispatch decision, discoverable at runtime: the full
            # debug_info() dict lands in the registry snapshot (one info
            # series per distinct resolved config), not just a DEBUG log
            # line that is lost unless logging was pre-configured.
            info = self.debug_info()
            reg.counter("engine.built_total", backend=self.backend,
                        resolved=info["resolved_backend"]).inc()
            reg.info("engine.dispatch", info, backend=self.backend,
                     resolved=info["resolved_backend"], N=self.code.N)
        if logger.isEnabledFor(logging.DEBUG):
            logger.debug("CodedComputeEngine: %s", self.debug_info())

    def debug_info(self) -> dict:
        """The engine's decode dispatch, resolved: requested vs chosen
        backend, the VMEM working-set estimate the choice was made on, and
        the concrete tile knobs the tiled kernels would run with."""
        resolved = resolve_backend(self.backend, self.code,
                                   adaptive=self.adaptive,
                                   vmem_budget_bytes=self.vmem_budget_bytes)
        return {
            "backend": self.backend,
            "resolved_backend": resolved,
            "vmem_bytes_estimate": vmem_bytes_estimate(self.code),
            "vmem_budget_bytes": self.vmem_budget_bytes,
            "bp": (self.bp if self.bp is not None else pick_tile_bp(
                self.code, vmem_budget_bytes=self.vmem_budget_bytes)),
            "bv": self.bv if self.bv is not None else 128,
            "N": self.code.N,
            "decode_iters": self.decode_iters,
            "adaptive": self.adaptive,
            "seeded_mode": self.seeded_mode,
            "schedule_cache_capacity": (
                None if self.schedule_cache is None
                else getattr(self.schedule_cache, "capacity", None)),
        }

    def _tile_kw(self) -> dict:
        return {"bp": self.bp, "bv": self.bv,
                "vmem_budget_bytes": self.vmem_budget_bytes,
                "seeded_mode": self.seeded_mode}

    def _schedule_kw(self, erased, *, batch: bool) -> dict:
        """``schedule=``/``schedules=`` operands for replay dispatch, from
        the engine's cache.  Only consulted for ``backend="replay"`` with a
        concrete mask — under jit the mask is a tracer and the decoder's
        own error message points the caller at pre-solving."""
        if (self.backend != "replay" or self.schedule_cache is None
                or isinstance(erased, jax.core.Tracer)):
            return {}
        if batch:
            return {"schedules": self.schedule_cache.get_batch(self.code,
                                                               erased)}
        return {"schedule": self.schedule_cache.get(self.code, erased)}

    def _record_decode(self, dec: DecodeResult) -> DecodeResult:
        """Feed eager decode outcomes into the obs registry.

        Strictly a host-side side channel: under jit/vmap the results are
        tracers and recording is skipped entirely (no new traced operands,
        no cache-key changes — the jitted consumers stay bit-identical).
        Eager callers pay one host fetch of the tiny stats arrays.
        """
        reg = _obs_metrics.active()
        if reg is None or isinstance(dec.erased, jax.core.Tracer):
            return dec
        rounds = np.atleast_1d(np.asarray(dec.rounds_used))
        erased = np.asarray(dec.erased)
        unres = (erased.sum(axis=-1) if erased.ndim > 1
                 else np.atleast_1d(erased.sum()))
        reg.histogram("engine.decode.rounds", bins=_obs_metrics.ROUND_BINS,
                      backend=self.backend).observe_many(rounds)
        reg.histogram("engine.decode.unresolved",
                      bins=_obs_metrics.COUNT_BINS,
                      backend=self.backend).observe_many(unres)
        return dec

    # -------------------------------------------------------------- stages

    @property
    def N(self) -> int:
        return self.code.N

    @property
    def K(self) -> int:
        return self.code.K

    def encode(self, payload: jax.Array) -> jax.Array:
        """(K, ...) systematic payload → (N, ...) worker symbols (G @ m)."""
        G = jnp.asarray(self.code.G, payload.dtype)
        return G @ payload

    @staticmethod
    def erase(symbols: jax.Array, mask: jax.Array) -> jax.Array:
        """Zero the straggled coordinates.  ``mask`` broadcasts from the
        right-aligned coordinate axis: (N,) against (N,), (N, V), or the
        batched (B, N) against (B, N), (B, N, V)."""
        m = mask
        while m.ndim < symbols.ndim:
            m = m[..., None]
        return jnp.where(m, 0.0, symbols)

    def decode(self, values: jax.Array, erased: jax.Array) -> DecodeResult:
        """One erasure pattern; values (N,) or (N, V) (payload axis)."""
        kw = {**self._tile_kw(), **self._schedule_kw(erased, batch=False)}
        if self.adaptive:
            # decode_iters doubles as the adaptive round budget (max_iters),
            # matching the pre-engine Scheme2 semantics.
            return self._record_decode(peel_decode_adaptive(
                self.code, values, erased, self.decode_iters,
                backend=self.backend, **kw))
        return self._record_decode(peel_decode(
            self.code, values, erased, self.decode_iters,
            backend=self.backend, **kw))

    def decode_batch(self, values: jax.Array, erased: jax.Array, *,
                     adaptive: bool | None = None,
                     budgets: jax.Array | None = None) -> DecodeResult:
        """B independent erasure patterns in ONE launch; values (B, N) or
        (B, N, V), erased (B, N).  Each slot decodes exactly as
        :meth:`decode` would decode it alone.

        ``adaptive`` overrides the engine's policy for this call (``None``
        = engine default).  Adaptive batches run the PER-SLOT early-exit
        decode (:func:`repro.core.decoder.peel_decode_batch_adaptive`): each
        slot stops at its own fixpoint under ``decode_iters`` (or its entry
        in ``budgets``, a traced per-slot round-budget vector), and
        ``rounds_used`` comes back as the per-slot ``(B,)`` stats vector —
        per-slot unresolved counts are ``result.erased.sum(axis=1)``.
        ``budgets`` is only meaningful for adaptive decodes."""
        use_adaptive = self.adaptive if adaptive is None else adaptive
        kw = {**self._tile_kw(), **self._schedule_kw(erased, batch=True)}
        if use_adaptive:
            return self._record_decode(peel_decode_batch_adaptive(
                self.code, values, erased, self.decode_iters,
                backend=self.backend, budgets=budgets, **kw))
        if budgets is not None:
            raise ValueError(
                "budgets= requires the adaptive batched decode (engine "
                "adaptive=True or decode_batch(adaptive=True)); the fixed-D "
                "path would silently ignore the per-slot round budgets")
        return self._record_decode(peel_decode_batch(
            self.code, values, erased, self.decode_iters,
            backend=self.backend, **kw))

    def systematic(self, dec: DecodeResult) -> tuple[jax.Array, jax.Array]:
        """Epilogue: zero-filled systematic part + its unresolved mask.

        Handles both single (values (N,)/(N,V)) and batched
        (values (B,N)/(B,N,V)) decode results; the systematic slice is the
        first K coordinates of the coordinate axis.
        """
        K = self.code.K
        batched = dec.erased.ndim == 2
        ax = 1 if batched else 0
        vals = jax.lax.slice_in_dim(dec.values, 0, K, axis=ax)
        unresolved = jax.lax.slice_in_dim(dec.erased, 0, K, axis=ax)
        m = unresolved
        while m.ndim < vals.ndim:
            m = m[..., None]
        return jnp.where(m, 0.0, vals), unresolved

    # ------------------------------------------------------- composed steps

    def recover(self, symbols: jax.Array, mask: jax.Array
                ) -> tuple[jax.Array, jax.Array]:
        """erase → decode → epilogue for one pattern: returns the
        zero-filled systematic (K, ...) values and the (K,) unresolved mask."""
        dec = self.decode(self.erase(symbols, mask), mask)
        return self.systematic(dec)

    def recover_batch(self, symbols: jax.Array, mask: jax.Array, *,
                      adaptive: bool | None = None,
                      budgets: jax.Array | None = None
                      ) -> tuple[jax.Array, jax.Array]:
        """erase → decode → epilogue for B patterns in one launch: returns
        (B, K, ...) zero-filled systematic values and (B, K) unresolved.
        ``adaptive`` / ``budgets`` pass through to :meth:`decode_batch`
        (per-slot early exit and round budgets)."""
        dec = self.decode_batch(self.erase(symbols, mask), mask,
                                adaptive=adaptive, budgets=budgets)
        return self.systematic(dec)
