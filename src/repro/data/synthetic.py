"""Synthetic data generators matching the paper's experimental setup, plus a
deterministic token pipeline for the transformer zoo.

Paper Section 4: X has i.i.d. random entries; y = X θ* (+ optional noise);
θ* dense (least squares) or u-sparse (sparse recovery), with both
overdetermined (m = 2048 > k) and underdetermined (m = 1024 < k = 2000)
regimes.
"""
from __future__ import annotations

from typing import Iterator, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["LinearProblem", "make_linear_problem", "make_sparse_problem", "token_batches"]


class LinearProblem(NamedTuple):
    X: jax.Array          # (m, k)
    y: jax.Array          # (m,)
    theta_star: jax.Array  # (k,)
    # suggested PGD learning rate: 1/λ_max(X^T X) (guaranteed descent for exact GD)
    lr: float


def _lr_for(X: np.ndarray) -> float:
    lam = np.linalg.norm(X, 2) ** 2  # λ_max(X^T X)
    return float(1.0 / lam)


def make_linear_problem(m: int, k: int, *, noise: float = 0.0, seed: int = 0,
                        normalize: bool = True) -> LinearProblem:
    """Dense least squares: X ~ N(0, 1/m)^{m x k}, y = X θ* + noise."""
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((m, k))
    if normalize:
        X /= np.sqrt(m)
    theta = rng.standard_normal(k)
    y = X @ theta + noise * rng.standard_normal(m)
    return LinearProblem(jnp.asarray(X, jnp.float32), jnp.asarray(y, jnp.float32),
                         jnp.asarray(theta, jnp.float32), _lr_for(X))


def make_sparse_problem(m: int, k: int, u: int, *, seed: int = 0,
                        normalize: bool = True) -> LinearProblem:
    """u-sparse θ*; covers both m > k (overdetermined) and m < k (IHT)."""
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((m, k))
    if normalize:
        X /= np.sqrt(m)
    theta = np.zeros(k)
    support = rng.choice(k, size=u, replace=False)
    theta[support] = rng.standard_normal(u)
    y = X @ theta
    return LinearProblem(jnp.asarray(X, jnp.float32), jnp.asarray(y, jnp.float32),
                         jnp.asarray(theta, jnp.float32), _lr_for(X))


def token_batches(vocab: int, batch: int, seq: int, *, seed: int = 0,
                  n_batches: int | None = None) -> Iterator[dict]:
    """Deterministic synthetic token stream for LLM training/smoke tests.

    Yields {"tokens": (batch, seq) int32, "labels": shifted} —
    a Zipf-ish distribution so losses are non-degenerate.
    """
    key = jax.random.PRNGKey(seed)
    i = 0
    while n_batches is None or i < n_batches:
        key, k1 = jax.random.split(key)
        # Zipf-ish: exponentiate a uniform to skew towards small ids.
        u = jax.random.uniform(k1, (batch, seq + 1), minval=1e-6, maxval=1.0)
        toks = jnp.minimum((u ** 3.0) * vocab, vocab - 1).astype(jnp.int32)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        i += 1
