"""Concrete batch construction per architecture family (smoke tests,
examples, CPU training drivers).  The modality frontends are stubs per the
assignment: audio frames / VLM patches arrive as embeddings at d_model."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

__all__ = ["make_batch", "make_decode_inputs"]


def make_batch(cfg: ArchConfig, batch: int, seq: int, key=None) -> dict:
    key = key if key is not None else jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    dt = cfg.jdtype
    if cfg.family == "vlm":
        n_text = seq - cfg.n_patches
        toks = jax.random.randint(k1, (batch, n_text), 0, cfg.vocab)
        return {
            "tokens": toks,
            "patches": jax.random.normal(k2, (batch, cfg.n_patches, cfg.d_model), dt),
        }
    if cfg.family == "audio":
        toks = jax.random.randint(k1, (batch, seq), 0, cfg.vocab)
        labels = jnp.roll(toks, -1, axis=1)
        return {
            "tokens": toks,
            "labels": labels,
            "frames": jax.random.normal(k2, (batch, cfg.enc_seq, cfg.d_model), dt),
        }
    toks = jax.random.randint(k1, (batch, seq + 1), 0, cfg.vocab)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_decode_inputs(cfg: ArchConfig, batch: int, key=None) -> dict:
    key = key if key is not None else jax.random.PRNGKey(1)
    return {"token": jax.random.randint(key, (batch, 1), 0, cfg.vocab)}
