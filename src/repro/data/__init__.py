from repro.data.synthetic import (
    LinearProblem,
    make_linear_problem,
    make_sparse_problem,
    token_batches,
)

__all__ = ["LinearProblem", "make_linear_problem", "make_sparse_problem", "token_batches"]
